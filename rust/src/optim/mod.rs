//! The optimizer zoo: HELENE plus every baseline the paper compares against.
//!
//! All zeroth-order optimizers share the MeZO step protocol driven by the
//! trainer (`train/`): perturb +εz → L⁺ → perturb −2εz → L⁻ → restore →
//! `step_zo(params, g_scale, seed)` where `g_scale = (L⁺ − L⁻) / 2ε` and
//! `z` is regenerated from `seed` inside the optimizer via the
//! shard-parallel `ParamSet::update_shards*` kernels (stateless v2
//! z-stream, DESIGN.md §Sharding). With `TrainConfig::fuse_restore` the
//! restore pass is folded into the update (`step_zo_fused`) — same
//! arithmetic, one fewer arena sweep — and with
//! `TrainConfig::prefetch_perturb` the NEXT step's `+εz` rides in the same
//! sweep too (`step_zo_fused_prefetch`), taking the steady state to two
//! arena sweeps per step. Under `TrainConfig::tiled_sweeps` the fused
//! prefetch sweep additionally runs **tile-by-tile** against a
//! staged-upload loss oracle (`step_zo_fused_prefetch_staged`, DESIGN.md
//! §Runtime) — HELENE, ZO-SGD, ZO-Adam and ZO-Sophia stream each finished
//! tile while sweeping the next; everyone else inherits a
//! sweep-then-stream default. Under `TrainConfig::probes` > 1 the trainer
//! feeds a whole batch of one-sided probe scalars at once
//! (`step_zo_multi{,_prefetch}`, DESIGN.md §Perf): the k-seed kernels
//! apply the combined basis `Σᵢ gᵢ·z(seedᵢ)` in a single sweep, taking
//! the steady state to q+1 sweeps per step — 1 + 1/q per probe.
//! First-order baselines receive the exact
//! gradient from the compiled `loss_grad` entrypoint through `step_fo`.
//!
//! **Arena codecs** (DESIGN.md §Precision): every update runs through the
//! `ParamSet::update_shards*` kernels, so the zoo is codec-agnostic — a
//! bf16 θ-arena is widened shard-by-shard into an f32 stage, the optimizer
//! arithmetic below runs unchanged, and θ′ is rounded once at the store.
//! Sweep count is also the *rounded-store* count in bf16 mode, which is
//! why the single-sweep fused overrides (HELENE/ZO-SGD/ZO-Adam/ZO-Sophia)
//! matter beyond bandwidth: the default `step_zo_fused` pays an extra
//! restore sweep, i.e. one extra bf16 rounding per element per step, and
//! the §Precision drift bounds quote the single-sweep figures. Optimizer
//! state (m/h/v) stays f32 for every codec.
//!
//! | paper name      | type                        | module        |
//! |-----------------|-----------------------------|---------------|
//! | HELENE          | [`helene::Helene`]          | `helene.rs`   |
//! | MeZO / ZO-SGD   | [`zo_sgd::ZoSgd`]           | `zo_sgd.rs`   |
//! | ZO-SGD-MMT      | [`zo_sgd::ZoSgdMomentum`]   | `zo_sgd.rs`   |
//! | ZO-SGD-Cons     | [`zo_sgd::ZoSgdCons`]       | `zo_sgd.rs`   |
//! | ZO-SGD-Sign     | [`zo_sgd::ZoSgdSign`]       | `zo_sgd.rs`   |
//! | ZO-Adam/AdamW   | [`zo_adam::ZoAdam`]         | `zo_adam.rs`  |
//! | ZO-Lion         | [`zo_adam::ZoLion`]         | `zo_adam.rs`  |
//! | ZO-Sophia       | [`sophia::ZoSophia`]        | `sophia.rs`   |
//! | diag-Newton(ZO) | [`newton::ZoNewton`]        | `newton.rs`   |
//! | FO-SGD          | [`fo::FoSgd`]               | `fo.rs`       |
//! | FO-Adam         | [`fo::FoAdam`]              | `fo.rs`       |
//! | Forward-Grad    | [`zo_sgd::ZoSgd`] + JVP     | trainer mode  |

pub mod anneal;
pub mod clip;
pub mod fo;
pub mod helene;
pub mod newton;
pub mod sophia;
pub mod spsa;
pub mod zo_adam;
pub mod zo_sgd;

use anyhow::Result;

use crate::model::params::{GradSource, ParamSet, ShardSeg, TileSpec, ZCache};
use crate::runtime::StagedThetaSink;

/// A staged-sweep request threaded through an optimizer's tiled fused
/// step (DESIGN.md §Runtime): the fused restore+update+prefetch sweep
/// runs tile-by-tile under `tiles`, handing each finished tile to `sink`
/// so its upload overlaps the next tile's sweep.
pub struct StagedSweep<'a> {
    /// the tile cover to sweep in
    pub tiles: TileSpec,
    /// where finished tiles are staged
    pub sink: &'a mut dyn StagedThetaSink,
}

/// The shared body of the two-state staged overrides (HELENE / ZO-Adam /
/// ZO-Sophia): run one dual-stream `update_tile2_dual` sweep tile-by-tile
/// under `sw.tiles`, staging each finished tile into `sw.sink` — so the
/// sink contract (one generation, arena order, abort-on-error) lives in
/// exactly one place instead of drifting across optimizers.
pub(crate) fn staged_dual2_sweep<F>(
    params: &mut ParamSet,
    s1: &mut ParamSet,
    s2: &mut ParamSet,
    src: GradSource<'_>,
    next_seed: u64,
    mut capture: Option<&mut ZCache>,
    sw: StagedSweep<'_>,
    f: F,
) -> Result<()>
where
    F: Fn(&ShardSeg, &mut [f32], &mut [f32], &mut [f32], &[f32], &[f32]) + Sync,
{
    sw.sink.begin_theta(params)?;
    for tile in params.theta_tiles(sw.tiles) {
        params.update_tile2_dual(
            &tile,
            s1,
            s2,
            src.reborrow(),
            next_seed,
            capture.as_deref_mut(),
            &f,
        );
        sw.sink.stage_tile(&tile, &params.tile_f32(&tile))?;
    }
    sw.sink.finish_theta()
}

/// Resolve a ZO step's gradient basis: the z-cache when provided (validated
/// against the parameter layout — a recoverable error, never the layout
/// assert), else seeded stateless regeneration. Shared by every
/// `step_zo_fused` implementation so the cache-validity contract lives in
/// one place.
pub fn zo_grad_src<'a>(
    name: &str,
    params: &ParamSet,
    seed: u64,
    cache: Option<&'a ZCache>,
) -> Result<GradSource<'a>> {
    match cache {
        Some(c) => {
            anyhow::ensure!(
                c.matches(params),
                "{name}: z-cache not filled for this parameter layout"
            );
            // seed-keyed staleness check: a mis-rotated or leftover buffer
            // would silently feed the wrong step's z into the update
            debug_assert!(
                c.seed() == seed,
                "{name}: stale z-cache (holds seed {}, step wants {seed})",
                c.seed(),
            );
            Ok(GradSource::Cached(c))
        }
        None => Ok(GradSource::Seeded(seed)),
    }
}

/// How the trainer must feed an optimizer each step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// SPSA two-point estimate: `step_zo(g_scale, seed)`.
    Zo,
    /// Exact gradient from `loss_grad`: `step_fo(grads)`.
    Fo,
    /// JVP along a seeded tangent (Forward-Grad): `step_zo(jvp, seed)`.
    ForwardGrad,
}

/// A training algorithm over a `ParamSet`.
///
/// `Send` is a supertrait so optimizers can be moved into distributed
/// worker threads (`crate::dist`); every optimizer state is plain
/// `Vec`/scalar data, so this costs nothing.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    fn kind(&self) -> StepKind;

    /// Allocate state buffers for the given parameter layout. Must be
    /// called once before stepping.
    fn init(&mut self, params: &ParamSet);

    /// Tell the optimizer the mini-batch size B (the A-GNB estimators use
    /// it; Algorithm 2 returns `B·ĝ⊙ĝ`). Called by the trainer before
    /// `init`. Default: ignored.
    fn configure_batch(&mut self, _batch_size: usize) {}

    /// Zeroth-order step. `g_scale` is the SPSA projected-gradient scalar
    /// (or the JVP value in ForwardGrad mode); `seed` regenerates `z`.
    fn step_zo(&mut self, _params: &mut ParamSet, _g_scale: f32, _seed: u64) -> Result<()> {
        anyhow::bail!("{} is not a zeroth-order optimizer", self.name())
    }

    /// Zeroth-order step with this step's z already materialised in `cache`
    /// (§Perf: saves the regeneration pass). Default: fall back to seeded
    /// regeneration — the cache holds exactly the draws `seed` would give.
    fn step_zo_cached(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        _cache: &crate::model::params::ZCache,
    ) -> Result<()> {
        self.step_zo(params, g_scale, seed)
    }

    /// Fused restore+update (§Perf): the trainer runs the probe pair via
    /// `spsa::estimate_*_unrestored`, which leaves `θ − εz`, and this step
    /// folds the owed `+εz` restore into the update. Per-element arithmetic
    /// is exactly "restore then step", so the fused path is bitwise
    /// identical to the unfused one (property-tested); the win is one fewer
    /// full arena sweep. The default does restore-then-step in two sweeps
    /// so every optimizer in the zoo keeps working; HELENE, ZO-SGD, ZO-Adam
    /// and ZO-Sophia override it with a single-sweep kernel. On error the
    /// restore may be left unapplied — callers abort the run in that case.
    fn step_zo_fused(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
    ) -> Result<()> {
        match zo_grad_src(self.name(), params, seed, cache)? {
            GradSource::Cached(c) => {
                params.perturb_from_cache(c, seed, eps);
                self.step_zo_cached(params, g_scale, seed, c)
            }
            _ => {
                params.perturb_trainable(seed, eps);
                self.step_zo(params, g_scale, seed)
            }
        }
    }

    /// Cross-step fused step (§Perf, prefetch protocol): everything
    /// [`Self::step_zo_fused`] does *plus* the NEXT step's `+ε·z(next_seed)`
    /// perturbation, leaving `θ_{k+1} + εz_{k+1}` so the following probe
    /// pair needs no opening perturb sweep — the trainer's steady state
    /// drops to two arena sweeps per step. `next_cache`, when given,
    /// captures the next step's draws seed-keyed for its probe passes (the
    /// rotating-buffer half of `TrainConfig::cache_z`). Per-element
    /// arithmetic is exactly restore → update → perturb, so the pipeline
    /// stays bitwise identical to the unfused protocol (property-tested).
    /// This default runs `step_zo_fused` then a separate prefetch sweep —
    /// correct for every optimizer in the zoo; HELENE, ZO-SGD, ZO-Adam and
    /// ZO-Sophia override it with a single dual-stream sweep
    /// (`ParamSet::update_shards*_dual`).
    #[allow(clippy::too_many_arguments)]
    fn step_zo_fused_prefetch(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        next_cache: Option<&mut crate::model::params::ZCache>,
    ) -> Result<()> {
        self.step_zo_fused(params, g_scale, seed, eps, cache)?;
        match next_cache {
            Some(nc) => params.perturb_fill_cache(nc, next_seed, eps),
            None => params.perturb_trainable(next_seed, eps),
        }
        Ok(())
    }

    /// Tiled θ-streaming flavour of [`Self::step_zo_fused_prefetch`]
    /// (DESIGN.md §Runtime): identical restore+update+prefetch arithmetic,
    /// but executed tile-by-tile under `tiles`, streaming every finished
    /// tile into `sink` — the next loss execution's staged upload — so the
    /// upload of tile *t* overlaps the sweep of tile *t+1*. Bitwise
    /// identical to the monolithic step for any tile size (tiling is pure
    /// scheduling; property-tested). This default runs the monolithic step
    /// and then streams the whole generation — correct for every optimizer
    /// in the zoo, with staged consumption but no sweep/upload overlap;
    /// HELENE, ZO-SGD, ZO-Adam and ZO-Sophia override it with a true
    /// per-tile dual-stream sweep (`ParamSet::update_tile{,2}_dual`).
    /// Sink errors abort the step like a failed fused sweep.
    #[allow(clippy::too_many_arguments)]
    fn step_zo_fused_prefetch_staged(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        next_cache: Option<&mut crate::model::params::ZCache>,
        tiles: TileSpec,
        sink: &mut dyn StagedThetaSink,
    ) -> Result<()> {
        self.step_zo_fused_prefetch(params, g_scale, seed, next_seed, eps, cache, next_cache)?;
        crate::runtime::stream_theta(params, tiles, sink)
    }

    /// Multi-probe zeroth-order step (DESIGN.md §Perf): apply the averaged
    /// q-probe update `Δθ ∝ Σᵢ gᵢ·z(seedᵢ)` where `probes` holds the
    /// `(seedᵢ, gᵢ)` pairs of `spsa::SpsaMultiEstimate::averaged_probes`.
    /// θ must arrive **pristine** — the multi estimator restores it before
    /// handing over. This default applies the probes as q sequential
    /// `step_zo` calls: exact for linear updates (ZO-SGD) but it advances
    /// a stateful optimizer's moments q times; HELENE, ZO-SGD and ZO-Adam
    /// override it with a single k-seed fused sweep that consumes all q
    /// probes in one moment update (`ParamSet::update_shards*_multi`).
    fn step_zo_multi(&mut self, params: &mut ParamSet, probes: &[(u64, f32)]) -> Result<()> {
        for &(seed, g) in probes {
            self.step_zo(params, g, seed)?;
        }
        Ok(())
    }

    /// Multi-probe step plus next-step prefetch: everything
    /// [`Self::step_zo_multi`] does *and* the next step's
    /// `+ε·z(next_seed)` perturbation, leaving `θ′ + εz` so the following
    /// multi estimate needs no opening perturb sweep — the q-probe steady
    /// state of `train::ZoProtocol` is q+1 sweeps per step (1 + 1/q per
    /// probe). `next_cache`, when given, captures the next step's probe-0
    /// draws seed-keyed for its probe passes. This default runs the multi
    /// step then a separate prefetch sweep; the fused overrides fold the
    /// prefetch stream into the same sweep
    /// (`ParamSet::update_shards*_multi_dual`).
    fn step_zo_multi_prefetch(
        &mut self,
        params: &mut ParamSet,
        probes: &[(u64, f32)],
        next_seed: u64,
        eps: f32,
        next_cache: Option<&mut crate::model::params::ZCache>,
    ) -> Result<()> {
        self.step_zo_multi(params, probes)?;
        match next_cache {
            Some(nc) => params.perturb_fill_cache(nc, next_seed, eps),
            None => params.perturb_trainable(next_seed, eps),
        }
        Ok(())
    }

    /// First-order step from exact gradients.
    fn step_fo(&mut self, _params: &mut ParamSet, _grads: &ParamSet) -> Result<()> {
        anyhow::bail!("{} is not a first-order optimizer", self.name())
    }

    /// Whether the trainer should evaluate the post-step loss and offer a
    /// revert (ZO-SGD-Cons). Default: no.
    fn wants_post_check(&self) -> bool {
        false
    }

    /// Fraction of coordinates clipped by the optimizer's most recent
    /// curvature clamp, if it keeps one (HELENE's layer-wise Hessian
    /// clipping telemetry). `None` (the default) means the optimizer has
    /// no clipping to report — distinct from `Some(0.0)`, which means
    /// clipping is live but nothing was clamped. Surfaced per-replica by
    /// the distributed tier ([`crate::dist::DistReport`]).
    fn clip_fraction(&self) -> Option<f64> {
        None
    }

    /// Post-step hook with (loss_before, loss_after); may revert the update.
    fn post_check(&mut self, _params: &mut ParamSet, _before: f32, _after: f32) -> Result<()> {
        Ok(())
    }

    /// Bytes of optimizer state held (paper §C.1 memory accounting).
    fn state_bytes(&self) -> usize;

    fn lr(&self) -> f32;

    fn set_lr(&mut self, lr: f32);
}

/// Construct any optimizer in the zoo by its paper name (bench/CLI entry).
pub fn by_name(name: &str, lr: f32) -> Result<Box<dyn Optimizer>> {
    Ok(match name {
        "helene" => Box::new(helene::Helene::paper_defaults().with_lr(lr)),
        "helene-fo" => Box::new(helene::Helene::paper_defaults().with_lr(lr).with_fo_hessian()),
        "mezo" | "zo-sgd" => Box::new(zo_sgd::ZoSgd::new(lr)),
        "zo-sgd-mmt" => Box::new(zo_sgd::ZoSgdMomentum::new(lr, 0.9)),
        "zo-sgd-cons" => Box::new(zo_sgd::ZoSgdCons::new(lr)),
        "zo-sgd-sign" => Box::new(zo_sgd::ZoSgdSign::new(lr)),
        "zo-adam" => Box::new(zo_adam::ZoAdam::new(lr, false)),
        "zo-adamw" => Box::new(zo_adam::ZoAdam::new(lr, true)),
        "zo-lion" => Box::new(zo_adam::ZoLion::new(lr)),
        "zo-sophia" => Box::new(sophia::ZoSophia::new(lr)),
        "zo-newton" => Box::new(newton::ZoNewton::new(lr)),
        "fo-sgd" => Box::new(fo::FoSgd::new(lr)),
        "fo-adam" => Box::new(fo::FoAdam::new(lr)),
        "forward-grad" => Box::new(zo_sgd::ZoSgd::new(lr).as_forward_grad()),
        other => anyhow::bail!("unknown optimizer {other:?}"),
    })
}

/// All ZO optimizer names (Table 3 grid).
pub const ZO_ZOO: &[&str] = &[
    "mezo", "zo-sgd-mmt", "zo-sgd-cons", "zo-sgd-sign", "zo-adam", "zo-adamw",
    "zo-lion", "zo-sophia", "helene",
];

/// Shared test fixture: a ParamSet over toy layer groups.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::model::params::ParamSet;

    /// One single-array layer group per entry of `sizes`, all values 0.5.
    pub fn toy_params(sizes: &[usize]) -> ParamSet {
        ParamSet::synthetic(sizes, 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_constructs_every_name() {
        for name in [
            "helene", "helene-fo", "mezo", "zo-sgd", "zo-sgd-mmt", "zo-sgd-cons",
            "zo-sgd-sign", "zo-adam", "zo-adamw", "zo-lion", "zo-sophia",
            "zo-newton", "fo-sgd", "fo-adam", "forward-grad",
        ] {
            let opt = by_name(name, 1e-3).unwrap();
            assert!((opt.lr() - 1e-3).abs() < 1e-9, "{name}");
        }
        assert!(by_name("nope", 1e-3).is_err());
    }

    #[test]
    fn kinds_are_consistent() {
        assert_eq!(by_name("mezo", 1e-3).unwrap().kind(), StepKind::Zo);
        assert_eq!(by_name("helene", 1e-3).unwrap().kind(), StepKind::Zo);
        assert_eq!(by_name("fo-adam", 1e-3).unwrap().kind(), StepKind::Fo);
        assert_eq!(by_name("forward-grad", 1e-3).unwrap().kind(), StepKind::ForwardGrad);
    }
}
