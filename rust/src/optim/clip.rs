//! Layer-wise Hessian clipping policies (paper §3.5, Theorem 1).
//!
//! HELENE clips the *Hessian diagonal*, not the Newton update: the
//! preconditioner denominator is `γ · max(h_i, λ_i) + ε`, with a
//! threshold λ_i chosen per layer. Policies:
//!
//! * `Constant(λ)` — one magnitude threshold everywhere (the paper's §B.2
//!   ablation sweeps this in {0.9, 1, 2, 3}).
//! * `LayerScaled { r }` — the theory-guided setting of Theorem 1:
//!   `λ_i = R_i / (2 √d_i)` with a shared radius R, so wide layers get a
//!   smaller floor (finer-grained curvature trust) and narrow layers a
//!   larger one. This is what reduces the convergence bound from O(d) to
//!   O(max_i d_i).
//! * `PerLayer(vec)` — explicit thresholds, one per layer group.

use std::ops::Range;

use anyhow::{bail, Result};

use crate::model::manifest::VariantSpec;
use crate::model::params::SHARD_SIZE;

/// Per-layer clipping threshold policy.
#[derive(Clone, Debug, PartialEq)]
pub enum ClipPolicy {
    /// one λ shared by every layer (the paper's default, λ = 1)
    Constant(f32),
    /// λ_i = r / (2·width_i): Theorem 1's width-scaled thresholds
    LayerScaled {
        /// the numerator r of the width-scaled rule
        r: f32,
    },
    /// explicit λ per layer group, in manifest layer order
    PerLayer(Vec<f32>),
}

impl ClipPolicy {
    /// Resolve λ for every layer group, given each group's dimension d_i.
    pub fn lambdas(&self, group_dims: &[usize]) -> Result<Vec<f32>> {
        match self {
            ClipPolicy::Constant(l) => {
                if *l <= 0.0 {
                    bail!("clip threshold must be positive, got {l}");
                }
                Ok(vec![*l; group_dims.len()])
            }
            ClipPolicy::LayerScaled { r } => {
                if *r <= 0.0 {
                    bail!("radius must be positive, got {r}");
                }
                Ok(group_dims
                    .iter()
                    .map(|&d| r / (2.0 * (d.max(1) as f32).sqrt()))
                    .collect())
            }
            ClipPolicy::PerLayer(v) => {
                if v.len() != group_dims.len() {
                    bail!("PerLayer has {} thresholds for {} groups", v.len(), group_dims.len());
                }
                if v.iter().any(|&l| l <= 0.0) {
                    bail!("all thresholds must be positive");
                }
                Ok(v.clone())
            }
        }
    }
}

impl Default for ClipPolicy {
    /// The paper's robust default: constant magnitude clipping at 1.0
    /// (§B.2: "problematic Hessian values are concentrated below 1").
    fn default() -> Self {
        ClipPolicy::Constant(1.0)
    }
}

/// Resolve λ for every parameter *array* by broadcasting each layer group's
/// λ to its member arrays — the lookup table the shard-parallel HELENE
/// kernel indexes by `ShardSeg::array`.
pub fn lambda_per_array(policy: &ClipPolicy, spec: &VariantSpec) -> Result<Vec<f32>> {
    let groups = spec.layer_groups();
    let dims: Vec<usize> = groups
        .iter()
        .map(|(_, idxs)| idxs.iter().map(|&i| spec.params[i].size).sum())
        .collect();
    let lambdas = policy.lambdas(&dims)?;
    let mut out = vec![0.0f32; spec.params.len()];
    for ((_, idxs), lam) in groups.iter().zip(&lambdas) {
        for &i in idxs {
            out[i] = *lam;
        }
    }
    Ok(out)
}

/// One layer group's footprint in the sharded flat arena: its resolved λ,
/// the contiguous element ranges its member arrays occupy, and the shard
/// indices those ranges touch (clip telemetry and the multi-worker
/// sharding plan both need the group ↔ shard correspondence).
#[derive(Clone, Debug)]
pub struct LayerSpans {
    /// layer group name
    pub layer: String,
    /// resolved clipping threshold λ for this group
    pub lambda: f32,
    /// maximal contiguous element ranges in the flat arena
    pub elem_ranges: Vec<Range<usize>>,
    /// maximal contiguous runs of shard indices covered by those ranges
    pub shard_ranges: Vec<Range<usize>>,
}

/// Map every layer group to its arena element ranges and the shards they
/// occupy, with λ resolved from `policy`.
pub fn layer_shard_spans(policy: &ClipPolicy, spec: &VariantSpec) -> Result<Vec<LayerSpans>> {
    let lam = lambda_per_array(policy, spec)?;
    Ok(spec
        .layer_groups()
        .into_iter()
        .map(|(layer, idxs)| {
            // merge adjacent member arrays into maximal element ranges
            let mut elem_ranges: Vec<Range<usize>> = Vec::new();
            for &i in &idxs {
                let p = &spec.params[i];
                let r = p.offset..p.offset + p.size;
                if r.is_empty() {
                    continue;
                }
                match elem_ranges.last_mut() {
                    Some(last) if last.end == r.start => last.end = r.end,
                    _ => elem_ranges.push(r),
                }
            }
            // shard indices touched by each element range, runs merged
            let mut shard_ranges: Vec<Range<usize>> = Vec::new();
            for r in &elem_ranges {
                let s = r.start / SHARD_SIZE..(r.end - 1) / SHARD_SIZE + 1;
                match shard_ranges.last_mut() {
                    Some(last) if last.end >= s.start => last.end = last.end.max(s.end),
                    _ => shard_ranges.push(s),
                }
            }
            let lambda = idxs.first().map_or(0.0, |&i| lam[i]);
            LayerSpans { layer, lambda, elem_ranges, shard_ranges }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_broadcasts() {
        let l = ClipPolicy::Constant(2.0).lambdas(&[10, 20, 30]).unwrap();
        assert_eq!(l, vec![2.0, 2.0, 2.0]);
        assert!(ClipPolicy::Constant(0.0).lambdas(&[1]).is_err());
    }

    #[test]
    fn layer_scaled_matches_theorem() {
        let dims = [4usize, 64, 1024];
        let l = ClipPolicy::LayerScaled { r: 1.0 }.lambdas(&dims).unwrap();
        for (i, &d) in dims.iter().enumerate() {
            let expect = 1.0 / (2.0 * (d as f32).sqrt());
            assert!((l[i] - expect).abs() < 1e-7);
        }
        // wider layer → smaller threshold
        assert!(l[0] > l[1] && l[1] > l[2]);
    }

    #[test]
    fn per_layer_validated() {
        assert!(ClipPolicy::PerLayer(vec![1.0, 2.0]).lambdas(&[3, 4]).is_ok());
        assert!(ClipPolicy::PerLayer(vec![1.0]).lambdas(&[3, 4]).is_err());
        assert!(ClipPolicy::PerLayer(vec![1.0, -1.0]).lambdas(&[3, 4]).is_err());
    }

    #[test]
    fn default_is_paper_constant_one() {
        assert_eq!(ClipPolicy::default(), ClipPolicy::Constant(1.0));
    }

    #[test]
    fn lambda_per_array_broadcasts_group_values() {
        // synthetic layout: one layer group per array
        let p = crate::model::params::ParamSet::synthetic(&[4, 100], 0.0);
        let lam = lambda_per_array(&ClipPolicy::LayerScaled { r: 1.0 }, &p.spec).unwrap();
        assert_eq!(lam.len(), 2);
        assert!((lam[0] - 1.0 / (2.0 * 2.0)).abs() < 1e-6);
        assert!((lam[1] - 1.0 / (2.0 * 10.0)).abs() < 1e-6);
    }

    #[test]
    fn layer_spans_cover_arena_and_map_to_shards() {
        // arrays straddle shard boundaries; groups are per-array here
        let sizes = [SHARD_SIZE + 100, 50, 3 * SHARD_SIZE];
        let p = crate::model::params::ParamSet::synthetic(&sizes, 0.0);
        let spans = layer_shard_spans(&ClipPolicy::Constant(1.0), &p.spec).unwrap();
        assert_eq!(spans.len(), 3);
        // element ranges tile the arena in order
        let mut pos = 0usize;
        for s in &spans {
            assert_eq!(s.elem_ranges.len(), 1);
            assert_eq!(s.elem_ranges[0].start, pos);
            pos = s.elem_ranges[0].end;
            assert_eq!(s.lambda, 1.0);
        }
        assert_eq!(pos, p.n_params());
        // layer0 spans shards 0..2 (it ends 100 elements into shard 1)
        assert_eq!(spans[0].shard_ranges, vec![0..2]);
        // layer1 lives entirely inside shard 1
        assert_eq!(spans[1].shard_ranges, vec![1..2]);
        // layer2 runs to the end of the arena
        assert_eq!(spans[2].shard_ranges.last().unwrap().end, p.n_shards());
    }
}
