//! Layer-wise Hessian clipping policies (paper §3.5, Theorem 1).
//!
//! HELENE clips the *Hessian diagonal*, not the Newton update: the
//! preconditioner denominator is `γ · max(h_i, λ_i) + ε`, with a
//! threshold λ_i chosen per layer. Policies:
//!
//! * `Constant(λ)` — one magnitude threshold everywhere (the paper's §B.2
//!   ablation sweeps this in {0.9, 1, 2, 3}).
//! * `LayerScaled { r }` — the theory-guided setting of Theorem 1:
//!   `λ_i = R_i / (2 √d_i)` with a shared radius R, so wide layers get a
//!   smaller floor (finer-grained curvature trust) and narrow layers a
//!   larger one. This is what reduces the convergence bound from O(d) to
//!   O(max_i d_i).
//! * `PerLayer(vec)` — explicit thresholds, one per layer group.

use anyhow::{bail, Result};

/// Per-layer clipping threshold policy.
#[derive(Clone, Debug, PartialEq)]
pub enum ClipPolicy {
    Constant(f32),
    LayerScaled { r: f32 },
    PerLayer(Vec<f32>),
}

impl ClipPolicy {
    /// Resolve λ for every layer group, given each group's dimension d_i.
    pub fn lambdas(&self, group_dims: &[usize]) -> Result<Vec<f32>> {
        match self {
            ClipPolicy::Constant(l) => {
                if *l <= 0.0 {
                    bail!("clip threshold must be positive, got {l}");
                }
                Ok(vec![*l; group_dims.len()])
            }
            ClipPolicy::LayerScaled { r } => {
                if *r <= 0.0 {
                    bail!("radius must be positive, got {r}");
                }
                Ok(group_dims
                    .iter()
                    .map(|&d| r / (2.0 * (d.max(1) as f32).sqrt()))
                    .collect())
            }
            ClipPolicy::PerLayer(v) => {
                if v.len() != group_dims.len() {
                    bail!("PerLayer has {} thresholds for {} groups", v.len(), group_dims.len());
                }
                if v.iter().any(|&l| l <= 0.0) {
                    bail!("all thresholds must be positive");
                }
                Ok(v.clone())
            }
        }
    }
}

impl Default for ClipPolicy {
    /// The paper's robust default: constant magnitude clipping at 1.0
    /// (§B.2: "problematic Hessian values are concentrated below 1").
    fn default() -> Self {
        ClipPolicy::Constant(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_broadcasts() {
        let l = ClipPolicy::Constant(2.0).lambdas(&[10, 20, 30]).unwrap();
        assert_eq!(l, vec![2.0, 2.0, 2.0]);
        assert!(ClipPolicy::Constant(0.0).lambdas(&[1]).is_err());
    }

    #[test]
    fn layer_scaled_matches_theorem() {
        let dims = [4usize, 64, 1024];
        let l = ClipPolicy::LayerScaled { r: 1.0 }.lambdas(&dims).unwrap();
        for (i, &d) in dims.iter().enumerate() {
            let expect = 1.0 / (2.0 * (d as f32).sqrt());
            assert!((l[i] - expect).abs() < 1e-7);
        }
        // wider layer → smaller threshold
        assert!(l[0] > l[1] && l[1] > l[2]);
    }

    #[test]
    fn per_layer_validated() {
        assert!(ClipPolicy::PerLayer(vec![1.0, 2.0]).lambdas(&[3, 4]).is_ok());
        assert!(ClipPolicy::PerLayer(vec![1.0]).lambdas(&[3, 4]).is_err());
        assert!(ClipPolicy::PerLayer(vec![1.0, -1.0]).lambdas(&[3, 4]).is_err());
    }

    #[test]
    fn default_is_paper_constant_one() {
        assert_eq!(ClipPolicy::default(), ClipPolicy::Constant(1.0));
    }
}
