//! ZO-SGD family: MeZO and the benchmark variants of Zhang et al. (2024).
//!
//! * `ZoSgd`       — MeZO / ZO-SGD: `θ −= η · g_scale · z` (Table 3 "ZO-SGD";
//!                   also serves Forward-Grad where g_scale is the JVP).
//! * `ZoSgdMomentum` — ZO-SGD-MMT: heavy-ball `m = μ m + g; θ −= η m`.
//! * `ZoSgdCons`   — ZO-SGD-Cons: conservative step — accept only if the
//!                   post-step loss did not increase, else revert exactly
//!                   (z regenerated from the step's seed).
//! * `ZoSgdSign`   — ZO-signSGD: `θ −= η · sign(g_scale · z)`.
//!
//! All updates run shard-parallel over the flat arena via the
//! `ParamSet::update_shards*` kernels / `perturb_trainable` (z regenerated
//! statelessly per position — DESIGN.md §Sharding).

use anyhow::{bail, Result};

use crate::model::params::{GradSource, ParamSet};
use crate::optim::{Optimizer, StepKind};

/// MeZO / ZO-SGD (optionally flagged as the Forward-Grad consumer).
pub struct ZoSgd {
    lr: f32,
    forward_grad: bool,
}

impl ZoSgd {
    /// MeZO / ZO-SGD with learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self { lr, forward_grad: false }
    }

    /// Same update rule, but the trainer feeds the JVP along z instead of
    /// the SPSA two-point estimate.
    pub fn as_forward_grad(mut self) -> Self {
        self.forward_grad = true;
        self
    }
}

impl Optimizer for ZoSgd {
    fn name(&self) -> &'static str {
        if self.forward_grad {
            "forward-grad"
        } else {
            "mezo"
        }
    }

    fn kind(&self) -> StepKind {
        if self.forward_grad {
            StepKind::ForwardGrad
        } else {
            StepKind::Zo
        }
    }

    fn init(&mut self, _params: &ParamSet) {}

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        // θ −= η · g_scale · z  — exactly MeZO's update; z regenerated.
        params.perturb_trainable(seed, -self.lr * g_scale);
        Ok(())
    }

    fn step_zo_cached(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        cache: &crate::model::params::ZCache,
    ) -> Result<()> {
        if !cache.matches(params) {
            bail!("zo-sgd: z-cache not filled for this parameter layout");
        }
        params.perturb_from_cache(cache, seed, -self.lr * g_scale);
        Ok(())
    }

    fn step_zo_fused(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
    ) -> Result<()> {
        // single sweep: θ += εz (restore) then θ −= η·g·z, per element —
        // same two ops the separate sweeps apply, so bitwise identical
        let scale = -self.lr * g_scale;
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        params.update_shards(src, |_seg, th, z| {
            for (x, zv) in th.iter_mut().zip(z) {
                *x += eps * zv;
                *x += scale * zv;
            }
        });
        Ok(())
    }

    fn step_zo_fused_prefetch(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        next_cache: Option<&mut crate::model::params::ZCache>,
    ) -> Result<()> {
        // single dual-stream sweep: restore + MeZO update on z_k, then the
        // next step's +εz on z_{k+1} — per-element identical to the three
        // separate sweeps
        let scale = -self.lr * g_scale;
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        params.update_shards_dual(src, next_seed, next_cache, |_seg, th, z, zn| {
            for (x, zv) in th.iter_mut().zip(z) {
                *x += eps * zv;
                *x += scale * zv;
            }
            for (x, zv) in th.iter_mut().zip(zn) {
                *x += eps * zv;
            }
        });
        Ok(())
    }

    fn step_zo_multi(&mut self, params: &mut ParamSet, probes: &[(u64, f32)]) -> Result<()> {
        // θ −= η · Σᵢ gᵢ·zᵢ — the combined q-probe basis applied by the
        // k-seed perturb kernel in ONE sweep (per-element identical to q
        // sequential single-seed updates; property-tested in params)
        let scaled: Vec<(u64, f32)> =
            probes.iter().map(|&(s, g)| (s, -self.lr * g)).collect();
        params.perturb_trainable_k(&scaled);
        Ok(())
    }

    fn step_zo_multi_prefetch(
        &mut self,
        params: &mut ParamSet,
        probes: &[(u64, f32)],
        next_seed: u64,
        eps: f32,
        next_cache: Option<&mut crate::model::params::ZCache>,
    ) -> Result<()> {
        // single dual-stream sweep: the combined q-probe update on
        // Σᵢ gᵢ·zᵢ, then the next step's +εz on z' — the multi analog of
        // step_zo_fused_prefetch (restore is not owed: the multi estimator
        // returns θ pristine)
        let lr = self.lr;
        params.update_shards_multi_dual(probes, next_seed, next_cache, |_seg, th, gz, zn| {
            for (x, gv) in th.iter_mut().zip(gz) {
                *x -= lr * gv;
            }
            for (x, zv) in th.iter_mut().zip(zn) {
                *x += eps * zv;
            }
        });
        Ok(())
    }

    fn step_zo_fused_prefetch_staged(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        mut next_cache: Option<&mut crate::model::params::ZCache>,
        tiles: crate::model::params::TileSpec,
        sink: &mut dyn crate::runtime::StagedThetaSink,
    ) -> Result<()> {
        // the dual-stream sweep of step_zo_fused_prefetch, tile-by-tile:
        // each finished tile is staged while the next tile is swept
        let scale = -self.lr * g_scale;
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        sink.begin_theta(params)?;
        for tile in params.theta_tiles(tiles) {
            params.update_tile_dual(
                &tile,
                src.reborrow(),
                next_seed,
                next_cache.as_deref_mut(),
                |_seg, th, z, zn| {
                    for (x, zv) in th.iter_mut().zip(z) {
                        *x += eps * zv;
                        *x += scale * zv;
                    }
                    for (x, zv) in th.iter_mut().zip(zn) {
                        *x += eps * zv;
                    }
                },
            );
            sink.stage_tile(&tile, &params.tile_f32(&tile))?;
        }
        sink.finish_theta()
    }

    fn state_bytes(&self) -> usize {
        0 // MeZO's selling point: zero optimizer state
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// ZO-SGD with heavy-ball momentum (ZO-SGD-MMT).
pub struct ZoSgdMomentum {
    lr: f32,
    mu: f32,
    m: Option<ParamSet>,
}

impl ZoSgdMomentum {
    /// Heavy-ball ZO-SGD with learning rate `lr` and momentum `mu`.
    pub fn new(lr: f32, mu: f32) -> Self {
        Self { lr, mu, m: None }
    }
}

impl Optimizer for ZoSgdMomentum {
    fn name(&self) -> &'static str {
        "zo-sgd-mmt"
    }

    fn kind(&self) -> StepKind {
        StepKind::Zo
    }

    fn init(&mut self, params: &ParamSet) {
        self.m = Some(params.zeros_like());
    }

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        let m = self.m.as_mut().ok_or_else(|| anyhow::anyhow!("init not called"))?;
        let (lr, mu) = (self.lr, self.mu);
        params.update_shards1(m, GradSource::Seeded(seed), |_seg, th, m_arr, z| {
            for j in 0..th.len() {
                m_arr[j] = mu * m_arr[j] + g_scale * z[j];
                th[j] -= lr * m_arr[j];
            }
        });
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.m.as_ref().map_or(0, |m| m.state_bytes())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Conservative ZO-SGD: revert the step when the loss got worse.
pub struct ZoSgdCons {
    lr: f32,
    last: Option<(f32, u64)>, // (g_scale, seed) of the pending step
    /// steps kept (post-check loss did not increase)
    pub accepted: u64,
    /// steps reverted by the post-check
    pub reverted: u64,
}

impl ZoSgdCons {
    /// Conservative ZO-SGD with learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self { lr, last: None, accepted: 0, reverted: 0 }
    }
}

impl Optimizer for ZoSgdCons {
    fn name(&self) -> &'static str {
        "zo-sgd-cons"
    }

    fn kind(&self) -> StepKind {
        StepKind::Zo
    }

    fn init(&mut self, _params: &ParamSet) {}

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        params.perturb_trainable(seed, -self.lr * g_scale);
        self.last = Some((g_scale, seed));
        Ok(())
    }

    fn wants_post_check(&self) -> bool {
        true
    }

    fn post_check(&mut self, params: &mut ParamSet, before: f32, after: f32) -> Result<()> {
        let Some((g_scale, seed)) = self.last.take() else {
            bail!("post_check without a pending step");
        };
        if after > before {
            // revert exactly: add back the same η·g·z values
            params.perturb_trainable(seed, self.lr * g_scale);
            self.reverted += 1;
        } else {
            self.accepted += 1;
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// ZO-signSGD: θ −= η · sign(g_scale · z).
pub struct ZoSgdSign {
    lr: f32,
}

impl ZoSgdSign {
    /// ZO-signSGD with learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self { lr }
    }
}

impl Optimizer for ZoSgdSign {
    fn name(&self) -> &'static str {
        "zo-sgd-sign"
    }

    fn kind(&self) -> StepKind {
        StepKind::Zo
    }

    fn init(&mut self, _params: &ParamSet) {}

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        if g_scale == 0.0 {
            return Ok(()); // sign(0) = 0: no update
        }
        let gs = g_scale.signum();
        let lr = self.lr;
        params.update_shards(GradSource::Seeded(seed), |_seg, th, z| {
            for j in 0..th.len() {
                th[j] -= lr * (gs * z[j]).signum();
            }
        });
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::toy_params;

    #[test]
    fn zo_sgd_matches_manual_axpy() {
        let mut p = toy_params(&[16]);
        let mut q = toy_params(&[16]);
        let mut opt = ZoSgd::new(0.01);
        opt.init(&p);
        opt.step_zo(&mut p, 0.5, 99).unwrap();
        // manual: θ += (-lr*g) * z
        q.perturb_trainable(99, -0.01 * 0.5);
        assert_eq!(p.flat(), q.flat());
        assert_eq!(opt.state_bytes(), 0);
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = toy_params(&[16]);
        let mut opt = ZoSgdMomentum::new(0.01, 0.9);
        opt.init(&p);
        // repeated identical gradients: displacement grows superlinearly
        let start = p.clone();
        opt.step_zo(&mut p, 1.0, 5).unwrap();
        let d1 = p.max_abs_diff(&start);
        opt.step_zo(&mut p, 1.0, 5).unwrap();
        let d2 = p.max_abs_diff(&start);
        assert!(d2 > 1.8 * d1, "momentum not accumulating: {d1} {d2}");
    }

    #[test]
    fn cons_reverts_bad_steps() {
        let mut p = toy_params(&[16]);
        let orig = p.clone();
        let mut opt = ZoSgdCons::new(0.05);
        opt.init(&p);
        opt.step_zo(&mut p, 1.0, 3).unwrap();
        assert!(opt.wants_post_check());
        opt.post_check(&mut p, 1.0, 2.0).unwrap(); // got worse → revert
        assert!(p.max_abs_diff(&orig) <= 2.0 * f32::EPSILON);
        assert_eq!((opt.accepted, opt.reverted), (0, 1));

        opt.step_zo(&mut p, 1.0, 4).unwrap();
        let moved = p.clone();
        opt.post_check(&mut p, 1.0, 0.5).unwrap(); // improved → keep
        assert_eq!(p.flat(), moved.flat());
        assert_eq!((opt.accepted, opt.reverted), (1, 1));
    }

    #[test]
    fn multi_step_is_bitwise_sequential_probes() {
        // the k-seed perturb kernel applies the probes as the same
        // sequential per-element axpys the default trait body would
        let probes = [(61u64, 0.3f32), (62, -0.2), (63, 0.05)];
        let mut a = toy_params(&[200, 120]);
        let mut b = toy_params(&[200, 120]);
        let mut opt = ZoSgd::new(0.01);
        opt.init(&a);
        opt.step_zo_multi(&mut a, &probes).unwrap();
        for &(seed, g) in &probes {
            b.perturb_trainable(seed, -0.01 * g);
        }
        assert_eq!(a.flat(), b.flat());
        assert_eq!(a.sweep_count(), 1, "one k-seed sweep for q probes");
    }

    #[test]
    fn multi_prefetch_parks_theta_at_next_probe_point() {
        let probes = [(71u64, 0.4f32), (72, 0.1)];
        let mut a = toy_params(&[150, 90]);
        let mut b = toy_params(&[150, 90]);
        let mut opt = ZoSgd::new(0.01);
        opt.init(&a);
        let mut cache = crate::model::params::ZCache::default();
        opt.step_zo_multi_prefetch(&mut a, &probes, 888, 1e-3, Some(&mut cache))
            .unwrap();
        // reference: combined-basis update then a separate perturb sweep
        let mut opt2 = ZoSgd::new(0.01);
        opt2.init(&b);
        opt2.step_zo_multi(&mut b, &probes).unwrap();
        b.perturb_trainable(888, 1e-3);
        assert!(a.max_abs_diff(&b) < 1e-6, "drift {}", a.max_abs_diff(&b));
        assert!(cache.matches_seed(&a, 888));
        assert_eq!(a.sweep_count(), 1, "fused multi+prefetch is one sweep");
    }

    #[test]
    fn sign_steps_are_constant_magnitude() {
        let mut p = toy_params(&[32]);
        let before = p.clone();
        let mut opt = ZoSgdSign::new(0.01);
        opt.init(&p);
        opt.step_zo(&mut p, -0.7, 11).unwrap();
        for (a, b) in p.array(0).iter().zip(before.array(0)) {
            assert!(((a - b).abs() - 0.01).abs() < 1e-7);
        }
        // zero gradient → no movement
        let frozen = p.clone();
        opt.step_zo(&mut p, 0.0, 12).unwrap();
        assert_eq!(p.flat(), frozen.flat());
    }
}
