//! First-order baselines (Table 3's FO-SGD; Tables 1-2's "FT" rows) fed by
//! the compiled `loss_grad` entrypoint. Also used for linear probing (the
//! trainer narrows the trainable mask to the head). Updates run
//! shard-parallel over the flat arena with `GradSource::Exact` (the
//! gradient set shares the arena layout, so the same kernels apply).

use anyhow::{anyhow, Result};

use crate::model::params::{GradSource, ParamSet};
use crate::optim::{Optimizer, StepKind};

/// Plain SGD: `θ −= η (g + wd·θ)`.
pub struct FoSgd {
    lr: f32,
    weight_decay: f32,
}

impl FoSgd {
    /// Plain SGD with learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self { lr, weight_decay: 0.0 }
    }

    /// Add (coupled) weight decay.
    pub fn with_weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }
}

impl Optimizer for FoSgd {
    fn name(&self) -> &'static str {
        "fo-sgd"
    }

    fn kind(&self) -> StepKind {
        StepKind::Fo
    }

    fn init(&mut self, _params: &ParamSet) {}

    fn step_fo(&mut self, params: &mut ParamSet, grads: &ParamSet) -> Result<()> {
        let (lr, wd) = (self.lr, self.weight_decay);
        params.update_shards(GradSource::Exact(grads), |_seg, th, g| {
            for j in 0..th.len() {
                th[j] -= lr * (g[j] + wd * th[j]);
            }
        });
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Adam (Kingma & Ba) — the paper's "FT with Adam" reference row.
pub struct FoAdam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    t: usize,
    m: Option<ParamSet>,
    v: Option<ParamSet>,
}

impl FoAdam {
    /// Adam with the textbook defaults and learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, t: 0, m: None, v: None }
    }
}

impl Optimizer for FoAdam {
    fn name(&self) -> &'static str {
        "fo-adam"
    }

    fn kind(&self) -> StepKind {
        StepKind::Fo
    }

    fn init(&mut self, params: &ParamSet) {
        self.m = Some(params.zeros_like());
        self.v = Some(params.zeros_like());
        self.t = 0;
    }

    fn step_fo(&mut self, params: &mut ParamSet, grads: &ParamSet) -> Result<()> {
        let (m, v) = match (&mut self.m, &mut self.v) {
            (Some(m), Some(v)) => (m, v),
            _ => return Err(anyhow!("init not called")),
        };
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (lr, beta1, beta2, eps, wd) =
            (self.lr, self.beta1, self.beta2, self.eps, self.weight_decay);
        params.update_shards2(m, v, GradSource::Exact(grads), |_seg, th, m_arr, v_arr, g| {
            for j in 0..th.len() {
                m_arr[j] = beta1 * m_arr[j] + (1.0 - beta1) * g[j];
                v_arr[j] = beta2 * v_arr[j] + (1.0 - beta2) * g[j] * g[j];
                let m_hat = m_arr[j] / bc1;
                let v_hat = v_arr[j] / bc2;
                th[j] -= lr * (m_hat / (v_hat.sqrt() + eps) + wd * th[j]);
            }
        });
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.m.as_ref().map_or(0, |m| m.state_bytes())
            + self.v.as_ref().map_or(0, |v| v.state_bytes())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::toy_params;

    #[test]
    fn sgd_exact_update() {
        let mut p = toy_params(&[8]);
        let mut g = p.zeros_like();
        g.array_mut(0).copy_from_slice(&[2.0; 8]);
        let mut opt = FoSgd::new(0.1);
        opt.init(&p);
        opt.step_fo(&mut p, &g).unwrap();
        for &x in p.array(0) {
            assert!((x - (0.5 - 0.2)).abs() < 1e-7);
        }
    }

    #[test]
    fn sgd_respects_mask() {
        let mut p = toy_params(&[8, 8]);
        p.train_mask[0] = false;
        let g = p.full_like(1.0);
        let mut opt = FoSgd::new(0.1);
        opt.init(&p);
        opt.step_fo(&mut p, &g).unwrap();
        assert!(p.array(0).iter().all(|&x| x == 0.5));
        assert!(p.array(1).iter().all(|&x| x != 0.5));
    }

    #[test]
    fn adam_quadratic_convergence() {
        // minimise f(x) = Σ x² with exact gradients 2x: Adam should reach
        // near-zero quickly
        let mut p = toy_params(&[16]);
        let mut opt = FoAdam::new(0.05);
        opt.init(&p);
        for _ in 0..200 {
            let mut g = p.zeros_like();
            for j in 0..16 {
                g.array_mut(0)[j] = 2.0 * p.array(0)[j];
            }
            opt.step_fo(&mut p, &g).unwrap();
        }
        let norm: f32 = p.array(0).iter().map(|x| x * x).sum();
        assert!(norm < 1e-4, "norm {norm}");
    }

    #[test]
    fn zo_step_rejected() {
        let mut p = toy_params(&[4]);
        let mut opt = FoSgd::new(0.1);
        opt.init(&p);
        assert!(opt.step_zo(&mut p, 1.0, 0).is_err());
    }
}
