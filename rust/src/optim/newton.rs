//! Naive diagonal Newton's method in the ZO setting — the unstable
//! second-order baseline of Figures 1-2.
//!
//! `θ −= η · g / (h + ε)` with h the raw (EMA-free, clip-free) A-GNB
//! estimate refreshed every step. With no floor on h, small curvature
//! estimates produce enormous steps and the method oscillates or diverges
//! on heterogeneous-curvature problems — exactly the failure mode HELENE's
//! layer-wise clipping repairs (the toy bench makes this visible).

use anyhow::{anyhow, Result};

use crate::model::params::{GradSource, ParamSet};
use crate::optim::{Optimizer, StepKind};

/// Diagonal-Newton ZO baseline: precondition by the raw z²-weighted
/// curvature estimate, no floor — the unstable reference HELENE's λ-clip
/// fixes (Figures 1-2).
pub struct ZoNewton {
    lr: f32,
    eps: f32,
    batch_size: f32,
    h: Option<ParamSet>,
}

impl ZoNewton {
    /// Diagonal ZO-Newton with learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self { lr, eps: 1e-12, batch_size: 8.0, h: None }
    }
}

impl Optimizer for ZoNewton {
    fn name(&self) -> &'static str {
        "zo-newton"
    }

    fn kind(&self) -> StepKind {
        StepKind::Zo
    }

    fn configure_batch(&mut self, batch_size: usize) {
        self.batch_size = batch_size as f32;
    }

    fn init(&mut self, params: &ParamSet) {
        self.h = Some(params.zeros_like());
    }

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        let h = self.h.as_mut().ok_or_else(|| anyhow!("init not called"))?;
        let (lr, eps, batch_size) = (self.lr, self.eps, self.batch_size);
        params.update_shards1(h, GradSource::Seeded(seed), |_seg, th, h_arr, z| {
            for j in 0..th.len() {
                let g = g_scale * z[j];
                h_arr[j] = batch_size * g * g; // raw estimate, no EMA
                th[j] -= lr * g / (h_arr[j] + eps);
            }
        });
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.h.as_ref().map_or(0, |h| h.state_bytes())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::toy_params;

    #[test]
    fn unclipped_newton_takes_huge_steps_on_flat_curvature() {
        // h = B g² and update = g / h = 1 / (B g): tiny gradients produce
        // giant steps — the instability the paper's Figure 1 shows.
        let mut p = toy_params(&[16]);
        let before = p.clone();
        let mut opt = ZoNewton::new(1e-3);
        opt.init(&p);
        opt.step_zo(&mut p, 1e-4, 7).unwrap();
        // expected magnitude ≈ lr / (B · |g|) = 1e-3/(8·1e-4·|z|) ≈ O(1)
        assert!(p.max_abs_diff(&before) > 0.1, "diff {}", p.max_abs_diff(&before));
    }

    #[test]
    fn deterministic() {
        let mut a = toy_params(&[8]);
        let mut b = toy_params(&[8]);
        let mut o1 = ZoNewton::new(1e-3);
        let mut o2 = ZoNewton::new(1e-3);
        o1.init(&a);
        o2.init(&b);
        o1.step_zo(&mut a, 0.3, 1).unwrap();
        o2.step_zo(&mut b, 0.3, 1).unwrap();
        assert_eq!(a.flat(), b.flat());
    }
}
