//! ZO-Adam / ZO-AdamW / ZO-Lion — the adaptive ZO baselines of Table 3 and
//! Figure 4. All consume the SPSA gradient `g = g_scale · z` (z regenerated
//! statelessly from the step seed) and apply the textbook first-order
//! update rule to it, shard-parallel via `ParamSet::update_shards*`.

use anyhow::{anyhow, Result};

use crate::model::params::{GradSource, ParamSet, PrefetchSpec};
use crate::optim::{Optimizer, StepKind};

/// ZO-Adam (and AdamW with decoupled weight decay).
pub struct ZoAdam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    decoupled: bool,
    t: usize,
    m: Option<ParamSet>,
    v: Option<ParamSet>,
}

impl ZoAdam {
    /// ZO-Adam (`decoupled = false`) or ZO-AdamW (`decoupled = true`).
    pub fn new(lr: f32, decoupled: bool) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: if decoupled { 0.01 } else { 0.0 },
            decoupled,
            t: 0,
            m: None,
            v: None,
        }
    }

    /// Override the weight-decay coefficient.
    pub fn with_weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    /// Shared shard-parallel update; a non-zero `restore_eps` folds the
    /// SPSA `θ += εz` restore into the same sweep (`step_zo_fused`), with
    /// per-element arithmetic identical to a separate restore pass; a
    /// `prefetch` additionally applies the next step's `+εz` after the
    /// update in the same sweep (`step_zo_fused_prefetch`).
    fn apply(
        &mut self,
        params: &mut ParamSet,
        src: GradSource<'_>,
        g_scale: f32,
        restore_eps: f32,
        prefetch: Option<PrefetchSpec<'_>>,
        staged: Option<crate::optim::StagedSweep<'_>>,
    ) -> Result<()> {
        let (m, v) = match (&mut self.m, &mut self.v) {
            (Some(m), Some(v)) => (m, v),
            _ => return Err(anyhow!("init not called")),
        };
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (lr, beta1, beta2, eps) = (self.lr, self.beta1, self.beta2, self.eps);
        let (decoupled, wd) = (self.decoupled, self.weight_decay);
        let kernel = |th: &mut [f32], m_arr: &mut [f32], v_arr: &mut [f32], z: &[f32]| {
            if restore_eps != 0.0 {
                for (x, zv) in th.iter_mut().zip(z) {
                    *x += restore_eps * zv;
                }
            }
            for j in 0..th.len() {
                let g = g_scale * z[j];
                m_arr[j] = beta1 * m_arr[j] + (1.0 - beta1) * g;
                v_arr[j] = beta2 * v_arr[j] + (1.0 - beta2) * g * g;
                let m_hat = m_arr[j] / bc1;
                let v_hat = v_arr[j] / bc2;
                if decoupled {
                    th[j] -= lr * wd * th[j];
                }
                th[j] -= lr * m_hat / (v_hat.sqrt() + eps);
            }
        };
        match prefetch {
            None => {
                debug_assert!(staged.is_none(), "staged sweeps require a prefetch");
                params.update_shards2(m, v, src, |_seg, th, m_arr, v_arr, z| {
                    kernel(th, m_arr, v_arr, z)
                })
            }
            Some(p) => {
                let ps = p.scale;
                let dual = |_seg: &crate::model::params::ShardSeg,
                            th: &mut [f32],
                            m_arr: &mut [f32],
                            v_arr: &mut [f32],
                            z: &[f32],
                            zn: &[f32]| {
                    kernel(&mut *th, &mut *m_arr, &mut *v_arr, z);
                    for (x, zv) in th.iter_mut().zip(zn) {
                        *x += ps * zv;
                    }
                };
                match staged {
                    None => params.update_shards2_dual(m, v, src, p.seed, p.capture, dual),
                    Some(sw) => crate::optim::staged_dual2_sweep(
                        params, m, v, src, p.seed, p.capture, sw, dual,
                    )?,
                }
            }
        }
        Ok(())
    }

    /// Multi-probe update core (DESIGN.md §Perf): the gradient is the
    /// combined q-probe basis `gz = Σᵢ gᵢ·zᵢ` built per shard by the
    /// k-seed kernels, so both Adam moments see one EMA update of the
    /// averaged gradient and t advances once per multi step. θ arrives
    /// pristine (the multi estimator restores it), so no fused restore is
    /// owed; `prefetch` arms the next step's probe 0 in the same sweep.
    fn apply_multi(
        &mut self,
        params: &mut ParamSet,
        probes: &[(u64, f32)],
        prefetch: Option<PrefetchSpec<'_>>,
    ) -> Result<()> {
        let (m, v) = match (&mut self.m, &mut self.v) {
            (Some(m), Some(v)) => (m, v),
            _ => return Err(anyhow!("init not called")),
        };
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (lr, beta1, beta2, eps) = (self.lr, self.beta1, self.beta2, self.eps);
        let (decoupled, wd) = (self.decoupled, self.weight_decay);
        let kernel = |th: &mut [f32], m_arr: &mut [f32], v_arr: &mut [f32], gz: &[f32]| {
            for j in 0..th.len() {
                let g = gz[j];
                m_arr[j] = beta1 * m_arr[j] + (1.0 - beta1) * g;
                v_arr[j] = beta2 * v_arr[j] + (1.0 - beta2) * g * g;
                let m_hat = m_arr[j] / bc1;
                let v_hat = v_arr[j] / bc2;
                if decoupled {
                    th[j] -= lr * wd * th[j];
                }
                th[j] -= lr * m_hat / (v_hat.sqrt() + eps);
            }
        };
        match prefetch {
            None => params.update_shards2_multi(m, v, probes, |_seg, th, m_arr, v_arr, gz| {
                kernel(th, m_arr, v_arr, gz)
            }),
            Some(p) => {
                let ps = p.scale;
                params.update_shards2_multi_dual(
                    m,
                    v,
                    probes,
                    p.seed,
                    p.capture,
                    |_seg: &crate::model::params::ShardSeg,
                     th: &mut [f32],
                     m_arr: &mut [f32],
                     v_arr: &mut [f32],
                     gz: &[f32],
                     zn: &[f32]| {
                        kernel(&mut *th, &mut *m_arr, &mut *v_arr, gz);
                        for (x, zv) in th.iter_mut().zip(zn) {
                            *x += ps * zv;
                        }
                    },
                )
            }
        }
        Ok(())
    }
}

impl Optimizer for ZoAdam {
    fn name(&self) -> &'static str {
        if self.decoupled {
            "zo-adamw"
        } else {
            "zo-adam"
        }
    }

    fn kind(&self) -> StepKind {
        StepKind::Zo
    }

    fn init(&mut self, params: &ParamSet) {
        self.m = Some(params.zeros_like());
        self.v = Some(params.zeros_like());
        self.t = 0;
    }

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        self.apply(params, GradSource::Seeded(seed), g_scale, 0.0, None, None)
    }

    fn step_zo_cached(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        cache: &crate::model::params::ZCache,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, Some(cache))?;
        self.apply(params, src, g_scale, 0.0, None, None)
    }

    fn step_zo_fused(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        self.apply(params, src, g_scale, eps, None, None)
    }

    fn step_zo_fused_prefetch(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        next_cache: Option<&mut crate::model::params::ZCache>,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        let prefetch = PrefetchSpec { seed: next_seed, scale: eps, capture: next_cache };
        self.apply(params, src, g_scale, eps, Some(prefetch), None)
    }

    fn step_zo_fused_prefetch_staged(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        next_cache: Option<&mut crate::model::params::ZCache>,
        tiles: crate::model::params::TileSpec,
        sink: &mut dyn crate::runtime::StagedThetaSink,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        let prefetch = PrefetchSpec { seed: next_seed, scale: eps, capture: next_cache };
        self.apply(
            params,
            src,
            g_scale,
            eps,
            Some(prefetch),
            Some(crate::optim::StagedSweep { tiles, sink }),
        )
    }

    fn step_zo_multi(&mut self, params: &mut ParamSet, probes: &[(u64, f32)]) -> Result<()> {
        self.apply_multi(params, probes, None)
    }

    fn step_zo_multi_prefetch(
        &mut self,
        params: &mut ParamSet,
        probes: &[(u64, f32)],
        next_seed: u64,
        eps: f32,
        next_cache: Option<&mut crate::model::params::ZCache>,
    ) -> Result<()> {
        let prefetch = PrefetchSpec { seed: next_seed, scale: eps, capture: next_cache };
        self.apply_multi(params, probes, Some(prefetch))
    }

    fn state_bytes(&self) -> usize {
        self.m.as_ref().map_or(0, |m| m.state_bytes())
            + self.v.as_ref().map_or(0, |v| v.state_bytes())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// ZO-Lion (Chen et al., 2024): sign of an interpolated momentum.
pub struct ZoLion {
    lr: f32,
    beta1: f32,
    beta2: f32,
    weight_decay: f32,
    m: Option<ParamSet>,
}

impl ZoLion {
    /// ZO-Lion with the reference defaults and learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self { lr, beta1: 0.9, beta2: 0.99, weight_decay: 0.0, m: None }
    }
}

impl Optimizer for ZoLion {
    fn name(&self) -> &'static str {
        "zo-lion"
    }

    fn kind(&self) -> StepKind {
        StepKind::Zo
    }

    fn init(&mut self, params: &ParamSet) {
        self.m = Some(params.zeros_like());
    }

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        let m = self.m.as_mut().ok_or_else(|| anyhow!("init not called"))?;
        let (lr, beta1, beta2, wd) = (self.lr, self.beta1, self.beta2, self.weight_decay);
        params.update_shards1(m, GradSource::Seeded(seed), |_seg, th, m_arr, z| {
            for j in 0..th.len() {
                let g = g_scale * z[j];
                // c_t = β₁ m + (1−β₁) g ; update = sign(c_t)
                let c = beta1 * m_arr[j] + (1.0 - beta1) * g;
                let upd = if c > 0.0 { 1.0 } else if c < 0.0 { -1.0 } else { 0.0 };
                th[j] -= lr * (upd + wd * th[j]);
                // m_t = β₂ m + (1−β₂) g
                m_arr[j] = beta2 * m_arr[j] + (1.0 - beta2) * g;
            }
        });
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.m.as_ref().map_or(0, |m| m.state_bytes())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::toy_params;

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, the very first Adam step is ≈ lr·sign(g)
        let mut p = toy_params(&[64]);
        let before = p.clone();
        let mut opt = ZoAdam::new(1e-2, false);
        opt.init(&p);
        opt.step_zo(&mut p, 0.8, 42).unwrap();
        for (a, b) in p.array(0).iter().zip(before.array(0)) {
            let step = (a - b).abs();
            assert!(step < 1.05e-2 && step > 0.9e-2, "step {step}");
        }
    }

    #[test]
    fn adamw_decays_weights_adam_does_not() {
        let run = |decoupled: bool| {
            let mut p = toy_params(&[32]);
            let mut opt = ZoAdam::new(1e-3, decoupled);
            opt.init(&p);
            // zero gradient steps: only decoupled decay moves params
            for s in 0..10 {
                opt.step_zo(&mut p, 0.0, s).unwrap();
            }
            p.array(0)[0]
        };
        assert_eq!(run(false), 0.5);
        assert!(run(true) < 0.5);
    }

    #[test]
    fn lion_steps_have_fixed_magnitude() {
        let mut p = toy_params(&[32]);
        let before = p.clone();
        let mut opt = ZoLion::new(5e-3);
        opt.init(&p);
        opt.step_zo(&mut p, 1.3, 7).unwrap();
        for (a, b) in p.array(0).iter().zip(before.array(0)) {
            assert!(((a - b).abs() - 5e-3).abs() < 1e-7);
        }
    }

    #[test]
    fn state_accounting() {
        let p = toy_params(&[128]);
        let mut adam = ZoAdam::new(1e-3, false);
        adam.init(&p);
        assert_eq!(adam.state_bytes(), 2 * p.state_bytes());
        let mut lion = ZoLion::new(1e-3);
        lion.init(&p);
        assert_eq!(lion.state_bytes(), p.state_bytes());
    }

    #[test]
    fn multi_single_probe_matches_step_zo_bitwise() {
        // q = 1 through the k-seed path: 0 + g·z == g·z for the nonzero
        // z-stream, so the Adam trajectory must agree bitwise
        let mut a = toy_params(&[200, 120]);
        let mut b = toy_params(&[200, 120]);
        let mut o1 = ZoAdam::new(1e-3, true);
        let mut o2 = ZoAdam::new(1e-3, true);
        o1.init(&a);
        o2.init(&b);
        for s in 0..3 {
            o1.step_zo(&mut a, 0.4, 50 + s).unwrap();
            o2.step_zo_multi(&mut b, &[(50 + s, 0.4)]).unwrap();
        }
        assert_eq!(a.flat(), b.flat());
    }

    #[test]
    fn multi_prefetch_matches_separate_perturb() {
        let probes = [(31u64, 0.2f32), (32u64, -0.15f32)];
        let mut a = toy_params(&[150, 90]);
        let mut b = toy_params(&[150, 90]);
        let mut o1 = ZoAdam::new(1e-3, false);
        let mut o2 = ZoAdam::new(1e-3, false);
        o1.init(&a);
        o2.init(&b);
        o1.step_zo_multi(&mut a, &probes).unwrap();
        a.perturb_trainable(777, 1e-3);
        let mut cache = crate::model::params::ZCache::default();
        o2.step_zo_multi_prefetch(&mut b, &probes, 777, 1e-3, Some(&mut cache))
            .unwrap();
        assert_eq!(a.flat(), b.flat());
        assert!(cache.matches_seed(&b, 777));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = toy_params(&[16]);
        let mut b = toy_params(&[16]);
        let mut o1 = ZoAdam::new(1e-3, true);
        let mut o2 = ZoAdam::new(1e-3, true);
        o1.init(&a);
        o2.init(&b);
        for s in 0..5 {
            o1.step_zo(&mut a, 0.4, s).unwrap();
            o2.step_zo(&mut b, 0.4, s).unwrap();
        }
        assert_eq!(a.flat(), b.flat());
    }
}
