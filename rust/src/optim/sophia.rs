//! ZO-Sophia: the Sophia optimizer (Liu et al., 2023) ported to the
//! zeroth-order setting — the paper's principal second-order baseline.
//!
//! Differences from HELENE that the paper's analysis (§3.5, §B.3) hinges on:
//!
//! 1. Sophia clips the **Newton update** `m / max(γ·h, ε)` elementwise to
//!    `[−ρ, +ρ]` (global ρ = 1), whereas HELENE clips the **Hessian** with a
//!    per-layer floor. Clipping the update discards gradient-magnitude
//!    information; §B.3 counts how often this triggers.
//! 2. Sophia's GNB Hessian estimator samples labels ŷ from the model
//!    distribution, adding estimation noise; HELENE's A-GNB uses true labels.
//!    In the ZO port the label-sampling noise is modelled as the documented
//!    multiplicative perturbation on the Hessian estimate (`label_noise`),
//!    matching GNB's extra variance without a label-generating model.
//!
//! Trigger telemetry (`clip_triggers`, `update_elems`) reproduces the §B.3
//! counting experiment; counters accumulate atomically across the
//! shard-parallel update.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

use crate::model::params::{GradSource, ParamSet, PrefetchSpec};
use crate::optim::{Optimizer, StepKind};
use crate::util::rng::{mix64, Pcg64};

/// ZO-Sophia: clipped second-order update from a GNB diagonal Hessian
/// EMA, driven by the SPSA gradient estimate (Table 3 baseline).
pub struct ZoSophia {
    /// learning rate η
    pub lr: f32,
    /// momentum EMA decay β₁
    pub beta1: f32,
    /// Hessian EMA decay β₂
    pub beta2: f32,
    /// γ scaling of the Hessian in the denominator
    pub gamma: f32,
    /// numerical floor in the denominator
    pub eps: f32,
    /// update clip radius (Sophia uses ρ = 1)
    pub rho: f32,
    /// Hessian refresh period k
    pub hessian_every_k: usize,
    /// mini-batch size B in the GNB estimator
    pub batch_size: f32,
    /// emulate GNB's sampled-label noise on the Hessian estimate
    pub label_noise: f32,
    t: usize,
    m: Option<ParamSet>,
    h: Option<ParamSet>,
    /// §B.3 telemetry: elements clamped at ±ρ in the counting window
    pub clip_triggers: u64,
    /// §B.3 telemetry: total elements updated in the counting window
    pub update_elems: u64,
}

impl ZoSophia {
    /// ZO-Sophia with the paper's defaults and learning rate `lr`.
    pub fn new(lr: f32) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.99,
            gamma: 1.0,
            eps: 1e-8,
            rho: 1.0,
            hessian_every_k: 10,
            batch_size: 8.0,
            label_noise: 0.5,
            t: 0,
            m: None,
            h: None,
            clip_triggers: 0,
            update_elems: 0,
        }
    }

    /// Disable the GNB sampled-label noise emulation.
    pub fn without_label_noise(mut self) -> Self {
        self.label_noise = 0.0;
        self
    }

    /// Reset the §B.3 trigger counters (interval-based counting).
    pub fn reset_triggers(&mut self) {
        self.clip_triggers = 0;
        self.update_elems = 0;
    }

    /// Fraction of updated elements clamped at ±ρ in the current window.
    pub fn trigger_rate(&self) -> f64 {
        if self.update_elems == 0 {
            0.0
        } else {
            self.clip_triggers as f64 / self.update_elems as f64
        }
    }

    /// Shared shard-parallel update. `seed` drives the GNB label-noise
    /// draw even when the z basis comes from the cache; a non-zero
    /// `restore_eps` folds the SPSA `θ += εz` restore into the same sweep
    /// (`step_zo_fused`), and a `prefetch` additionally applies the next
    /// step's `+εz` after the update (`step_zo_fused_prefetch`) — both
    /// per-element identical to the separate sweeps.
    fn apply(
        &mut self,
        params: &mut ParamSet,
        src: GradSource<'_>,
        seed: u64,
        g_scale: f32,
        restore_eps: f32,
        prefetch: Option<PrefetchSpec<'_>>,
        staged: Option<crate::optim::StagedSweep<'_>>,
    ) -> Result<()> {
        let (m, h) = match (&mut self.m, &mut self.h) {
            (Some(m), Some(h)) => (m, h),
            _ => return Err(anyhow!("init not called")),
        };
        self.t += 1;
        let refresh_h = self.t % self.hessian_every_k.max(1) == 1 % self.hessian_every_k.max(1);
        // GNB label-sampling noise: one multiplicative draw per refresh
        // (sampled labels perturb the whole mini-batch estimate coherently)
        let noise_u = if refresh_h && self.label_noise > 0.0 {
            let mut nrng = Pcg64::new_stream(mix64(seed, 0x50F1A), 1);
            (1.0 + self.label_noise * nrng.next_normal()).max(0.0)
        } else {
            1.0
        };

        let (lr, beta1, beta2, gamma, eps, rho) =
            (self.lr, self.beta1, self.beta2, self.gamma, self.eps, self.rho);
        let batch_size = self.batch_size;
        let triggers = AtomicU64::new(0);
        let elems = AtomicU64::new(0);
        let kernel = |th: &mut [f32], m_arr: &mut [f32], h_arr: &mut [f32], z: &[f32]| {
            if restore_eps != 0.0 {
                // fused +εz restore: same per-element op as the standalone
                // restore sweep, so the fused path stays bitwise identical
                for (x, zv) in th.iter_mut().zip(z) {
                    *x += restore_eps * zv;
                }
            }
            let mut seg_triggers = 0u64;
            for j in 0..th.len() {
                let g = g_scale * z[j];
                m_arr[j] = beta1 * m_arr[j] + (1.0 - beta1) * g;
                if refresh_h {
                    let h_hat = batch_size * (g * noise_u) * (g * noise_u);
                    h_arr[j] = beta2 * h_arr[j] + (1.0 - beta2) * h_hat;
                }
                // Sophia update: clip(m / max(γ h, ε), ρ)
                let raw = m_arr[j] / (gamma * h_arr[j]).max(eps);
                let clipped = raw.clamp(-rho, rho);
                if raw != clipped {
                    seg_triggers += 1;
                }
                th[j] -= lr * clipped;
            }
            triggers.fetch_add(seg_triggers, Ordering::Relaxed);
            elems.fetch_add(th.len() as u64, Ordering::Relaxed);
        };
        match prefetch {
            None => {
                debug_assert!(staged.is_none(), "staged sweeps require a prefetch");
                params.update_shards2(m, h, src, |_seg, th, m_arr, h_arr, z| {
                    kernel(th, m_arr, h_arr, z)
                })
            }
            Some(p) => {
                let ps = p.scale;
                let dual = |_seg: &crate::model::params::ShardSeg,
                            th: &mut [f32],
                            m_arr: &mut [f32],
                            h_arr: &mut [f32],
                            z: &[f32],
                            zn: &[f32]| {
                    kernel(&mut *th, &mut *m_arr, &mut *h_arr, z);
                    for (x, zv) in th.iter_mut().zip(zn) {
                        *x += ps * zv;
                    }
                };
                match staged {
                    None => params.update_shards2_dual(m, h, src, p.seed, p.capture, dual),
                    Some(sw) => crate::optim::staged_dual2_sweep(
                        params, m, h, src, p.seed, p.capture, sw, dual,
                    )?,
                }
            }
        }
        self.clip_triggers += triggers.into_inner();
        self.update_elems += elems.into_inner();
        Ok(())
    }
}

impl Optimizer for ZoSophia {
    fn name(&self) -> &'static str {
        "zo-sophia"
    }

    fn kind(&self) -> StepKind {
        StepKind::Zo
    }

    fn configure_batch(&mut self, batch_size: usize) {
        self.batch_size = batch_size as f32;
    }

    fn init(&mut self, params: &ParamSet) {
        self.m = Some(params.zeros_like());
        self.h = Some(params.zeros_like());
        self.t = 0;
    }

    fn step_zo(&mut self, params: &mut ParamSet, g_scale: f32, seed: u64) -> Result<()> {
        self.apply(params, GradSource::Seeded(seed), seed, g_scale, 0.0, None, None)
    }

    fn step_zo_cached(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        cache: &crate::model::params::ZCache,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, Some(cache))?;
        self.apply(params, src, seed, g_scale, 0.0, None, None)
    }

    fn step_zo_fused(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        self.apply(params, src, seed, g_scale, eps, None, None)
    }

    fn step_zo_fused_prefetch(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        next_cache: Option<&mut crate::model::params::ZCache>,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        let prefetch = PrefetchSpec { seed: next_seed, scale: eps, capture: next_cache };
        self.apply(params, src, seed, g_scale, eps, Some(prefetch), None)
    }

    fn step_zo_fused_prefetch_staged(
        &mut self,
        params: &mut ParamSet,
        g_scale: f32,
        seed: u64,
        next_seed: u64,
        eps: f32,
        cache: Option<&crate::model::params::ZCache>,
        next_cache: Option<&mut crate::model::params::ZCache>,
        tiles: crate::model::params::TileSpec,
        sink: &mut dyn crate::runtime::StagedThetaSink,
    ) -> Result<()> {
        let src = crate::optim::zo_grad_src(self.name(), params, seed, cache)?;
        let prefetch = PrefetchSpec { seed: next_seed, scale: eps, capture: next_cache };
        self.apply(
            params,
            src,
            seed,
            g_scale,
            eps,
            Some(prefetch),
            Some(crate::optim::StagedSweep { tiles, sink }),
        )
    }

    fn state_bytes(&self) -> usize {
        self.m.as_ref().map_or(0, |m| m.state_bytes())
            + self.h.as_ref().map_or(0, |h| h.state_bytes())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::toy_params;

    #[test]
    fn update_magnitude_bounded_by_rho() {
        let mut p = toy_params(&[64]);
        let before = p.clone();
        let mut opt = ZoSophia::new(1e-2);
        opt.init(&p);
        opt.step_zo(&mut p, 2.0, 3).unwrap();
        for (a, b) in p.array(0).iter().zip(before.array(0)) {
            assert!((a - b).abs() <= 1e-2 * opt.rho + 1e-7);
        }
    }

    #[test]
    fn triggers_counted_when_h_small() {
        // fresh h ≈ tiny → |m/h| huge → every element clips
        let mut p = toy_params(&[64]);
        let mut opt = ZoSophia::new(1e-3).without_label_noise();
        opt.init(&p);
        opt.step_zo(&mut p, 1.0, 9).unwrap();
        assert!(opt.trigger_rate() > 0.5, "rate {}", opt.trigger_rate());
        opt.reset_triggers();
        assert_eq!(opt.clip_triggers, 0);
        assert_eq!(opt.update_elems, 0);
    }

    #[test]
    fn label_noise_changes_hessian_trajectory() {
        let run = |noise: f32| {
            let mut p = toy_params(&[32]);
            let mut opt = ZoSophia::new(1e-3);
            opt.label_noise = noise;
            opt.init(&p);
            for s in 0..20 {
                opt.step_zo(&mut p, 0.7, 1000 + s).unwrap();
            }
            p
        };
        let clean = run(0.0);
        let noisy = run(0.8);
        assert!(clean.max_abs_diff(&noisy) > 0.0);
    }

    #[test]
    fn fused_step_matches_restore_then_step() {
        // the new single-sweep fused kernel must be bitwise the default
        // restore-then-step sequence, trigger telemetry included
        let eps = 1e-3f32;
        let mut a = toy_params(&[300, 100]);
        let mut b = toy_params(&[300, 100]);
        let mut oa = ZoSophia::new(1e-3);
        let mut ob = ZoSophia::new(1e-3);
        oa.init(&a);
        ob.init(&b);
        for s in 0..4 {
            let seed = 50 + s;
            // park both replicas at θ − εz (the owed-restore probe state)
            for p in [&mut a, &mut b] {
                p.perturb_trainable(seed, eps);
                p.perturb_trainable(seed, -2.0 * eps);
            }
            // a: separate restore sweep, then the plain step
            a.perturb_trainable(seed, eps);
            oa.step_zo(&mut a, 0.4, seed).unwrap();
            // b: fused restore+update sweep
            ob.step_zo_fused(&mut b, 0.4, seed, eps, None).unwrap();
        }
        assert_eq!(a.flat(), b.flat());
        assert_eq!(oa.clip_triggers, ob.clip_triggers);
        assert_eq!(oa.update_elems, ob.update_elems);
    }

    #[test]
    fn prefetch_step_matches_step_then_perturb() {
        let eps = 1e-3f32;
        let (seed, next_seed) = (9u64, 10u64);
        let mut a = toy_params(&[128, 64]);
        let mut b = a.clone();
        let mut oa = ZoSophia::new(1e-3);
        let mut ob = ZoSophia::new(1e-3);
        oa.init(&a);
        ob.init(&b);
        for p in [&mut a, &mut b] {
            p.perturb_trainable(seed, eps);
            p.perturb_trainable(seed, -2.0 * eps);
        }
        oa.step_zo_fused(&mut a, 0.7, seed, eps, None).unwrap();
        a.perturb_trainable(next_seed, eps);
        let mut captured = crate::model::params::ZCache::default();
        ob.step_zo_fused_prefetch(&mut b, 0.7, seed, next_seed, eps, None, Some(&mut captured))
            .unwrap();
        assert_eq!(a.flat(), b.flat());
        assert!(captured.matches_seed(&b, next_seed));
        // the captured draws drive the next probe pass exactly
        b.perturb_from_cache(&captured, next_seed, -eps);
        a.perturb_trainable(next_seed, -eps);
        assert_eq!(a.flat(), b.flat());
    }

    #[test]
    fn state_is_two_extra_sets() {
        let p = toy_params(&[100]);
        let mut opt = ZoSophia::new(1e-3);
        opt.init(&p);
        assert_eq!(opt.state_bytes(), 2 * p.state_bytes());
    }
}
