//! The gradient-annealing schedule (paper §3.3.1, Algorithm 1 Subroutine).
//!
//! `α(t) = β₁ + (1 − β₁) · exp(−t / T)`
//!
//! α is the weight of the *current* gradient in the biased momentum
//! accumulator `m_t = β₁ m_{t−1} + α g_t`. Early in training α ≈ 1 (strong,
//! deliberately biased injection of fresh gradient — fast progress); as
//! t → ∞, α → β₁, so the accumulator tends to the standard discounted form
//! and the EMA bias the paper's Figure 5 ablation identifies is annealed
//! away.

/// Annealing schedule, single hyper-parameter `t_anneal` (the paper's T).
#[derive(Clone, Copy, Debug)]
pub struct Anneal {
    /// EMA decay β₁
    pub beta1: f32,
    /// annealing time constant T
    pub t_anneal: f32,
}

impl Anneal {
    /// An annealing schedule with decay `beta1` and time constant `t_anneal`.
    pub fn new(beta1: f32, t_anneal: f32) -> Self {
        assert!((0.0..1.0).contains(&beta1), "beta1 in [0,1)");
        assert!(t_anneal > 0.0);
        Self { beta1, t_anneal }
    }

    /// α at step t (Equation 1).
    pub fn alpha(&self, t: usize) -> f32 {
        self.beta1 + (1.0 - self.beta1) * (-(t as f32) / self.t_anneal).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_one_decays_to_beta1() {
        let a = Anneal::new(0.9, 1000.0);
        assert!((a.alpha(0) - 1.0).abs() < 1e-6);
        assert!(a.alpha(10_000_000) - 0.9 < 1e-6);
        assert!(a.alpha(10_000_000) >= 0.9);
    }

    #[test]
    fn monotone_decreasing() {
        let a = Anneal::new(0.5, 100.0);
        let mut prev = f32::INFINITY;
        for t in 0..1000 {
            let x = a.alpha(t);
            assert!(x <= prev);
            prev = x;
        }
    }

    #[test]
    fn half_life_at_t() {
        // at t = T the excess over beta1 has decayed by e
        let a = Anneal::new(0.8, 500.0);
        let excess0 = a.alpha(0) - 0.8;
        let excess_t = a.alpha(500) - 0.8;
        assert!((excess_t / excess0 - (-1.0f32).exp()).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_beta() {
        Anneal::new(1.5, 100.0);
    }
}
