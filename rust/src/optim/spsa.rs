//! SPSA two-point gradient estimation with MeZO's seeded in-place protocol.
//!
//! For loss L and perturbation scale ε (paper §2.1):
//!
//! ```text
//! θ ← θ + εz ;  L⁺ = L(θ)
//! θ ← θ − 2εz;  L⁻ = L(θ)
//! θ ← θ + εz              (restore)
//! g_scale = (L⁺ − L⁻) / 2ε        — the projected gradient  zᵀ∇L
//! ```
//!
//! `z ~ N(0, I)` is regenerated from the step seed at every use and never
//! materialised, so the extra memory is zero — the property that lets MeZO
//! (and HELENE on top of it) train with inference-level memory.
//!
//! The `*_unrestored` variants stop after L⁻, leaving `θ − εz`: the trainer
//! then calls `Optimizer::step_zo_fused`, which folds the `+εz` restore
//! into the optimizer's update sweep — one fewer full pass over the arena
//! per step with bit-identical arithmetic (§Perf, property-tested in
//! `tests/shard_determinism.rs`).
//!
//! The `*_preperturbed` variants additionally assume θ **arrives at
//! `θ + εz`** — perturbed by the previous step's fused prefetch sweep
//! (`Optimizer::step_zo_fused_prefetch`) or by a prologue perturb — so the
//! opening `+εz` sweep disappears too: one probe pair costs a single
//! `−2εz` arena sweep, and the steady-state step is two sweeps total
//! (`train::ZoProtocol`).
//!
//! [`estimate_multi_preperturbed`] batches q **one-sided** probes against a
//! shared baseline: q−1 fused seed-transition sweeps plus one restore sweep
//! produce q probe losses and the baseline, and the fused multi update
//! sweep closes the step at q+1 sweeps total — 1 + 1/q amortized sweeps
//! per probe, below the two-sweeps-per-probe floor of the pairwise
//! protocol (DESIGN.md §Perf, `TrainConfig::probes`).
//!
//! The estimator is generic over the loss oracle so the same code drives
//! the PJRT model runner, the 2-D toy problems, and the unit tests.
//!
//! Probe-loss hygiene (every estimator path, pairwise and multi): a
//! non-finite loss (NaN/±Inf) from the oracle aborts the step with
//! step-seed context **after** restoring θ, before the value can poison
//! the gradient scalar or the optimizer moment state.

use anyhow::Result;

use crate::model::params::ParamSet;

/// One SPSA measurement.
#[derive(Clone, Copy, Debug)]
pub struct SpsaEstimate {
    /// zᵀ∇L estimate: feed to `Optimizer::step_zo` together with `seed`.
    pub g_scale: f32,
    /// seed that regenerates this step's z
    pub seed: u64,
    /// loss at the +ε probe point
    pub loss_plus: f32,
    /// loss at the −ε probe point
    pub loss_minus: f32,
}

impl SpsaEstimate {
    /// The loss value reported for this step (mean of the two probes —
    /// an unbiased estimate of L(θ) to O(ε²)).
    pub fn loss(&self) -> f32 {
        0.5 * (self.loss_plus + self.loss_minus)
    }
}

/// Canonical aggregation of distributed per-shard partial losses
/// (`crate::dist`): one left-fold in f64 over the partials **in global
/// shard order**, rounded to f32 exactly once at the end. Fixing the
/// fold order and the rounding point here makes the total loss bitwise
/// independent of how shards are grouped into worker spans — the
/// N-invariance the distributed property tests gate on. Single-process
/// reference paths that want to be comparable to a distributed run must
/// total their loss through this same fold.
pub fn fold_partial_losses<I>(partials: I) -> f32
where
    I: IntoIterator<Item = f64>,
{
    let mut acc = 0.0f64;
    for p in partials {
        acc += p;
    }
    acc as f32
}

/// Cached probe pair **without the restore pass**: on success `params` is
/// left at `θ − εz` and the caller owes a `+εz` restore — normally folded
/// into the optimizer update via `Optimizer::step_zo_fused`, which turns
/// restore + update into a single arena sweep (§Perf). The z draws live in
/// `cache` for the −2ε pass and the fused step. On error `params` IS fully
/// restored before returning.
pub fn estimate_cached_unrestored<F>(
    params: &mut ParamSet,
    cache: &mut crate::model::params::ZCache,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    params.perturb_fill_cache(cache, seed, eps);
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, -eps);
            return Err(e);
        }
    };
    if !loss_plus.is_finite() {
        params.perturb_from_cache(cache, seed, -eps);
        anyhow::bail!(
            "non-finite loss {loss_plus} at the +ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    params.perturb_from_cache(cache, seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, eps);
            return Err(e);
        }
    };
    if !loss_minus.is_finite() {
        params.perturb_from_cache(cache, seed, eps);
        anyhow::bail!(
            "non-finite loss {loss_minus} at the −ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Cached variant of [`estimate_with`]: the z draws are generated once into
/// `cache` (one RNG pass) and reused for the −2ε and restore passes —
/// identical arithmetic, ~2 RNG passes saved per step (§Perf). Costs one
/// trainable-sized scratch buffer (`TrainConfig::cache_z`).
pub fn estimate_cached<F>(
    params: &mut ParamSet,
    cache: &mut crate::model::params::ZCache,
    seed: u64,
    eps: f32,
    loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    let est = estimate_cached_unrestored(params, cache, seed, eps, loss_fn)?;
    params.perturb_from_cache(cache, seed, eps);
    Ok(est)
}

/// Probe pair for the cross-step prefetch protocol: `params` must arrive
/// **already at `θ + εz(seed)`** (left there by the previous step's fused
/// prefetch sweep, or by a prologue perturb at a run boundary). L⁺ is
/// measured immediately, one `−2εz` sweep reaches the L⁻ point, and on
/// success `params` is left at `θ − εz` with the `+εz` restore owed to the
/// optimizer step — two probe losses for a single arena sweep. On error
/// `params` is returned to the unperturbed θ (up to the usual f32 re-add
/// drift) and the caller must abandon the pipeline.
pub fn estimate_preperturbed<F>(
    params: &mut ParamSet,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, -eps); // unwind the prefetch
            return Err(e);
        }
    };
    if !loss_plus.is_finite() {
        params.perturb_trainable(seed, -eps); // unwind the prefetch
        anyhow::bail!(
            "non-finite loss {loss_plus} at the +ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    params.perturb_trainable(seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, eps);
            return Err(e);
        }
    };
    if !loss_minus.is_finite() {
        params.perturb_trainable(seed, eps);
        anyhow::bail!(
            "non-finite loss {loss_minus} at the −ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Cached flavour of [`estimate_preperturbed`]: the draws of `seed` must
/// already sit in `cache` — captured by the previous step's fused prefetch
/// sweep or by the prologue `perturb_fill_cache`. The seed key is checked
/// up front (a mis-rotated buffer is a recoverable error, caught before
/// anything touches θ); the `−2εz` sweep then reuses the cached draws.
pub fn estimate_cached_preperturbed<F>(
    params: &mut ParamSet,
    cache: &crate::model::params::ZCache,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    anyhow::ensure!(
        cache.matches_seed(params, seed),
        "z-cache does not hold the draws of seed {seed} for this layout \
         (holds seed {}, filled: {})",
        cache.seed(),
        cache.is_filled(),
    );
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, -eps);
            return Err(e);
        }
    };
    if !loss_plus.is_finite() {
        params.perturb_from_cache(cache, seed, -eps);
        anyhow::bail!(
            "non-finite loss {loss_plus} at the +ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    params.perturb_from_cache(cache, seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, eps);
            return Err(e);
        }
    };
    if !loss_minus.is_finite() {
        params.perturb_from_cache(cache, seed, eps);
        anyhow::bail!(
            "non-finite loss {loss_minus} at the −ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Tiled flavour of the pre-perturbed probe pair (DESIGN.md §Runtime,
/// tiled θ-streaming): θ must arrive at `θ + εz(seed)` **with that
/// generation already staged in `sink`** (by the previous step's staged
/// fused sweep or a staged prologue). L⁺ executes from the staged
/// generation via `exec`; the `−2εz` sweep then runs **tile-by-tile**,
/// streaming each tile into `sink` as soon as it is produced — on an
/// async upload path tile *t+1*'s sweep overlaps tile *t*'s upload, and
/// on the host the stage copy reads the cache-hot tile — and L⁻ executes
/// from the freshly staged `θ − εz`. `cache` selects the cached-draw or
/// seeded-regeneration sweep (`TrainConfig::cache_z`); arithmetic is
/// bitwise the monolithic [`estimate_cached_preperturbed`] /
/// [`estimate_preperturbed`] pair for any tile size.
///
/// On an `exec` error θ is restored to the unperturbed point exactly like
/// the monolithic estimators; a `sink` error aborts mid-sweep and the
/// caller must abandon the run (same contract as a failed fused sweep).
pub fn estimate_staged_preperturbed<S, F>(
    params: &mut ParamSet,
    cache: Option<&crate::model::params::ZCache>,
    seed: u64,
    eps: f32,
    tiles: crate::model::params::TileSpec,
    sink: &mut S,
    mut exec: F,
) -> Result<SpsaEstimate>
where
    S: crate::runtime::StagedThetaSink + ?Sized,
    F: FnMut(&mut S) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    if let Some(c) = cache {
        anyhow::ensure!(
            c.matches_seed(params, seed),
            "z-cache does not hold the draws of seed {seed} for this layout \
             (holds seed {}, filled: {})",
            c.seed(),
            c.is_filled(),
        );
    }
    let loss_plus = match exec(sink) {
        Ok(l) => l,
        Err(e) => {
            match cache {
                Some(c) => params.perturb_from_cache(c, seed, -eps),
                None => params.perturb_trainable(seed, -eps),
            }
            return Err(e);
        }
    };
    if !loss_plus.is_finite() {
        match cache {
            Some(c) => params.perturb_from_cache(c, seed, -eps),
            None => params.perturb_trainable(seed, -eps),
        }
        anyhow::bail!(
            "non-finite loss {loss_plus} at the +ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    sink.begin_theta(params)?;
    for tile in params.theta_tiles(tiles) {
        match cache {
            Some(c) => params.perturb_tile_from_cache(&tile, c, seed, -2.0 * eps),
            None => params.perturb_tile(&tile, seed, -2.0 * eps),
        }
        sink.stage_tile(&tile, &params.tile_f32(&tile))?;
    }
    sink.finish_theta()?;
    let loss_minus = match exec(sink) {
        Ok(l) => l,
        Err(e) => {
            match cache {
                Some(c) => params.perturb_from_cache(c, seed, eps),
                None => params.perturb_trainable(seed, eps),
            }
            return Err(e);
        }
    };
    if !loss_minus.is_finite() {
        match cache {
            Some(c) => params.perturb_from_cache(c, seed, eps),
            None => params.perturb_trainable(seed, eps),
        }
        anyhow::bail!(
            "non-finite loss {loss_minus} at the −ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Probe pair **without the restore pass** (seeded-regeneration flavour of
/// [`estimate_cached_unrestored`]): on success `params` is left at
/// `θ − εz`; the caller owes the `+εz` restore (`Optimizer::step_zo_fused`
/// folds it into the update sweep). On error `params` IS fully restored.
pub fn estimate_unrestored<F>(
    params: &mut ParamSet,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    params.perturb_trainable(seed, eps);
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, -eps); // restore before bailing
            return Err(e);
        }
    };
    if !loss_plus.is_finite() {
        params.perturb_trainable(seed, -eps); // restore before bailing
        anyhow::bail!(
            "non-finite loss {loss_plus} at the +ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    params.perturb_trainable(seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, eps);
            return Err(e);
        }
    };
    if !loss_minus.is_finite() {
        params.perturb_trainable(seed, eps);
        anyhow::bail!(
            "non-finite loss {loss_minus} at the −ε probe (step seed {seed}): \
             aborting the step before it poisons the gradient estimate and \
             optimizer state"
        );
    }
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Run the perturb → probe → restore cycle against an arbitrary loss oracle.
/// On success `params` is restored (up to f32 re-add drift, see `ParamSet`).
pub fn estimate_with<F>(
    params: &mut ParamSet,
    seed: u64,
    eps: f32,
    loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    let est = estimate_unrestored(params, seed, eps, loss_fn)?;
    params.perturb_trainable(seed, eps);
    Ok(est)
}

/// One q-probe batched SPSA measurement (multi-probe protocol, DESIGN.md
/// §Perf). Each probe i is a **one-sided** difference against a shared
/// baseline:
///
/// ```text
/// g_i = (L(θ + εz_i) − L(θ)) / ε
/// ```
///
/// so q probes cost q+1 probe losses and — via the seed-transition chain
/// of [`estimate_multi_preperturbed`] — q+1 arena sweeps instead of the
/// 2q sweeps of q independent two-point pairs. The per-probe scalars are
/// stored **raw**; the trainer divides by q via
/// [`averaged_probes`](SpsaMultiEstimate::averaged_probes) so the
/// combined update estimates the same gradient a single probe does, with
/// the variance reduced by the averaging.
#[derive(Clone, Debug)]
pub struct SpsaMultiEstimate {
    /// `(seed_i, g_i)` per probe — raw one-sided projections, **not** yet
    /// divided by q. `seed_0` is the step seed itself ([`probe_seed`]),
    /// so q = 1 rides the same prefetch perturbation as the classic
    /// single-probe protocol.
    pub probes: Vec<(u64, f32)>,
    /// Loss at each `θ + εz_i` probe point, in probe order.
    pub losses: Vec<f32>,
    /// Shared baseline loss L(θ) at the unperturbed point.
    pub loss_base: f32,
}

impl SpsaMultiEstimate {
    /// `(seed_i, g_i / q)` pairs — the coefficients of the averaged
    /// q-probe gradient estimate `(1/q) Σᵢ gᵢ zᵢ`, ready to feed
    /// `Optimizer::step_zo_multi`.
    pub fn averaged_probes(&self) -> Vec<(u64, f32)> {
        let inv_q = 1.0 / self.probes.len() as f32;
        self.probes.iter().map(|&(s, g)| (s, g * inv_q)).collect()
    }

    /// The loss value reported for this step: the shared baseline L(θ) —
    /// exact at the unperturbed point, unlike the two-point mean, which
    /// is only O(ε²) close.
    pub fn loss(&self) -> f32 {
        self.loss_base
    }
}

/// Seed of probe `i` within the step of seed `step_seed`. Probe 0 **is**
/// the step seed, so the cross-step prefetch machinery — which perturbs
/// `+εz(next_seed)` during the update sweep — arms the next step's probe
/// 0 with no changes; further probes derive through `mix64`, giving each
/// an independent z-stream (`znorm::zbits` avalanche).
#[inline]
pub fn probe_seed(step_seed: u64, i: usize) -> u64 {
    if i == 0 {
        step_seed
    } else {
        crate::util::rng::mix64(step_seed, i as u64)
    }
}

/// q-probe batched estimate for the multi-probe steady state: `params`
/// must arrive **already at `θ + εz(probe_seed(step_seed, 0))`** — left
/// there by the previous step's fused multi prefetch sweep, or by a
/// prologue perturb at a run boundary. The chain then runs
///
/// ```text
/// L_0 at θ + εz_0                       (0 sweeps — prefetched)
/// θ ← θ − εz_i + εz_{i+1} ;  L_{i+1}    (q−1 fused transition sweeps)
/// θ ← θ − εz_{q−1}                      (1 sweep → pristine θ)
/// L_base = L(θ)                         (shared baseline)
/// ```
///
/// — q+1 probe losses for q arena sweeps; the fused multi update sweep
/// (which also prefetches the next step's probe 0) closes the step at
/// q+1 sweeps ≡ 1 + 1/q sweeps per probe (DESIGN.md §Perf).
///
/// Probe-loss hygiene: a non-finite loss (NaN/Inf) from the oracle
/// aborts the step with a contextful error **before** the value can
/// poison the gradient scalars or the optimizer moment state. On any
/// error θ is restored to the pristine point (up to the usual f32 re-add
/// drift) and the caller must abandon the pipeline.
pub fn estimate_multi_preperturbed<F>(
    params: &mut ParamSet,
    step_seed: u64,
    q: usize,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaMultiEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    anyhow::ensure!(q >= 1, "multi-probe estimate needs q >= 1 probes, got {q}");
    let seeds: Vec<u64> = (0..q).map(|i| probe_seed(step_seed, i)).collect();
    let mut losses = Vec::with_capacity(q);
    for i in 0..q {
        let l = match loss_fn(params) {
            Ok(l) => l,
            Err(e) => {
                params.perturb_trainable(seeds[i], -eps); // unwind probe i
                return Err(e.context(format!(
                    "probe {i} of {q} (seed {}, step seed {step_seed})",
                    seeds[i]
                )));
            }
        };
        if !l.is_finite() {
            params.perturb_trainable(seeds[i], -eps);
            anyhow::bail!(
                "non-finite loss {l} at probe {i} of {q} (seed {}, step seed \
                 {step_seed}): aborting the step before it poisons the \
                 gradient estimate and optimizer state",
                seeds[i]
            );
        }
        losses.push(l);
        if i + 1 < q {
            // fused transition: retire probe i, arm probe i+1 — one sweep
            params.perturb_trainable2(seeds[i], -eps, seeds[i + 1], eps);
        } else {
            params.perturb_trainable(seeds[i], -eps); // back to pristine θ
        }
    }
    // θ is pristine here, so a failing/non-finite baseline owes no restore.
    let loss_base = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            return Err(e.context(format!("baseline probe (step seed {step_seed})")));
        }
    };
    anyhow::ensure!(
        loss_base.is_finite(),
        "non-finite baseline loss {loss_base} (step seed {step_seed}): \
         aborting the step before it poisons the gradient estimate",
    );
    let probes = seeds
        .iter()
        .zip(&losses)
        .map(|(&s, &l)| (s, (l - loss_base) / eps))
        .collect();
    Ok(SpsaMultiEstimate { probes, losses, loss_base })
}

/// Cached flavour of [`estimate_multi_preperturbed`]: the draws of probe
/// 0 (= `step_seed`) must already sit in `cache` — captured by the
/// previous step's fused multi prefetch sweep or by the prologue
/// `perturb_fill_cache`. The seed key is checked up front, so a
/// mis-rotated buffer is a recoverable error caught before anything
/// touches θ; the transition chain itself regenerates streams from their
/// seeds, which the k-seed kernels fold into the same pass as the
/// arithmetic.
pub fn estimate_multi_cached_preperturbed<F>(
    params: &mut ParamSet,
    cache: &crate::model::params::ZCache,
    step_seed: u64,
    q: usize,
    eps: f32,
    loss_fn: F,
) -> Result<SpsaMultiEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    anyhow::ensure!(
        cache.matches_seed(params, step_seed),
        "z-cache does not hold the draws of seed {step_seed} for this layout \
         (holds seed {}, filled: {})",
        cache.seed(),
        cache.is_filled(),
    );
    estimate_multi_preperturbed(params, step_seed, q, eps, loss_fn)
}

/// Hyperparameters of the FZOO-style ε adaptation ([`EpsSchedule`]).
///
/// The schedule multiplies ε each step by `anneal + gain · r`, where
/// `r ∈ [0, 1)` is the variance-normalized spread of the step's q raw
/// one-sided probe scalars (see [`EpsSchedule::update`]). `anneal < 1`
/// gives HELENE-style geometric annealing toward small probe scales as
/// the run converges; `gain` lets a noisy probe ensemble (spread
/// comparable to the mean projection — the FZOO curvature signal) slow
/// or reverse the shrink. The multiplied ε is clamped to
/// `[min_ratio · ε₀, max_ratio · ε₀]` so a pathological loss surface can
/// never run ε to 0 or ∞.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpsAdaptConfig {
    /// Geometric annealing factor applied every step (`0 < anneal`,
    /// normally `< 1`). With q = 1 the spread is identically zero and the
    /// schedule is pure geometric annealing `ε ← anneal · ε`.
    pub anneal: f32,
    /// Gain on the variance-normalized probe spread `r ∈ [0, 1)`; the
    /// per-step factor is `anneal + gain · r`. `0` disables the
    /// spread-driven term.
    pub gain: f32,
    /// Lower clamp for ε as a ratio of the configured ε₀ (`> 0`).
    pub min_ratio: f32,
    /// Upper clamp for ε as a ratio of the configured ε₀
    /// (`>= min_ratio`).
    pub max_ratio: f32,
}

impl Default for EpsAdaptConfig {
    fn default() -> Self {
        Self { anneal: 0.98, gain: 0.04, min_ratio: 0.05, max_ratio: 4.0 }
    }
}

impl EpsAdaptConfig {
    /// Reject non-finite or degenerate hyperparameters with a named-field
    /// error (mirrors `TrainConfig::validate_robustness`).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.anneal.is_finite() && self.anneal > 0.0,
            "adapt-eps anneal must be finite and > 0, got {}",
            self.anneal
        );
        anyhow::ensure!(
            self.gain.is_finite() && self.gain >= 0.0,
            "adapt-eps gain must be finite and >= 0, got {}",
            self.gain
        );
        anyhow::ensure!(
            self.min_ratio.is_finite() && self.min_ratio > 0.0,
            "adapt-eps min-ratio must be finite and > 0, got {}",
            self.min_ratio
        );
        anyhow::ensure!(
            self.max_ratio.is_finite() && self.max_ratio >= self.min_ratio,
            "adapt-eps max-ratio must be finite and >= min-ratio {}, got {}",
            self.min_ratio,
            self.max_ratio
        );
        Ok(())
    }
}

/// The bf16 ε floor `mean|θ|/256` of DESIGN.md §Precision: one bf16
/// store rounds with relative error up to 2⁻⁹, so a perturbation below
/// this floor sits at stored-codec rounding-noise scale and the SPSA
/// difference signal drowns. Returns `None` for non-bf16 arenas, empty
/// parameter sets, or an all-zero arena (no meaningful floor). Shared by
/// the trainer's `eps_floor_clamp` heuristic and by [`EpsSchedule`]
/// construction (single-process and distributed), so every ε consumer
/// computes the identical floor bits from the same arena.
pub fn bf16_eps_floor(params: &ParamSet) -> Option<f32> {
    if params.codec() != crate::model::params::Codec::Bf16 {
        return None;
    }
    let flat = params.flat_f32();
    if flat.is_empty() {
        return None;
    }
    let mean_abs =
        (flat.iter().map(|x| x.abs() as f64).sum::<f64>() / flat.len() as f64) as f32;
    let floor = mean_abs / 256.0;
    (floor > 0.0).then_some(floor)
}

/// Deterministic FZOO-style ε schedule driven by the q raw one-sided
/// probe scalars of each step ([`SpsaMultiEstimate::probes`]).
///
/// Update rule (all statistics in f64, folded **in probe order**, with a
/// single f64→f32 rounding at the end — the fixed-order arithmetic that
/// makes the schedule a pure function of `(ε bits, probe scalar bits)`
/// and therefore bitwise identical across thread counts, transports, and
/// replay):
///
/// ```text
/// mean   = (1/q) Σᵢ gᵢ
/// spread = sqrt((1/q) Σᵢ (gᵢ − mean)²)
/// r      = spread / (|mean| + spread + 1e-30)      ∈ [0, 1)
/// ε ← clamp(ε · (anneal + gain · r), ε₀·min_ratio, ε₀·max_ratio)
/// ```
///
/// followed by the bf16 ε-floor clamp when the schedule was built with a
/// floor (DESIGN.md §Precision): adapted ε is never allowed below
/// `mean|θ|/256` — the drift bounds of the bf16 arena assume probes stay
/// above the stored-codec rounding noise — and crossing the floor warns
/// once per schedule instance, matching `eps_floor_clamp`.
///
/// The distributed coordinator and the single-process `ZoProtocol` feed
/// this identical raw scalars (same f32 `(Lᵢ − L_base)/ε` op order), so
/// identically-constructed schedules produce bit-identical ε
/// trajectories — the `eps_adapt_bitwise` CI gate.
#[derive(Clone, Debug)]
pub struct EpsSchedule {
    cfg: EpsAdaptConfig,
    lo: f32,
    hi: f32,
    floor: Option<f32>,
    eps: f32,
    floor_warned: bool,
}

impl EpsSchedule {
    /// A schedule starting at `eps0`, clamped to
    /// `[min_ratio · eps0, max_ratio · eps0]`, with an optional hard
    /// lower floor (the bf16 `mean|θ|/256` heuristic — pass `None` in
    /// f32 mode). `eps0` must already respect the floor (the run
    /// boundary's `eps_floor_clamp` guarantees this).
    pub fn new(cfg: EpsAdaptConfig, eps0: f32, floor: Option<f32>) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            eps0.is_finite() && eps0 > 0.0,
            "adapt-eps needs a finite positive starting ε, got {eps0}"
        );
        Ok(Self {
            cfg,
            lo: cfg.min_ratio * eps0,
            hi: cfg.max_ratio * eps0,
            floor,
            eps: eps0,
            floor_warned: false,
        })
    }

    /// The ε the next step's probes should use.
    pub fn eps(&self) -> f32 {
        self.eps
    }

    /// Fold one step's raw probe scalars (seed, gᵢ) into the schedule and
    /// return the adapted ε for the **next** step. `probes` must be the
    /// raw (undivided) scalars in probe order; an empty slice leaves ε
    /// unchanged.
    pub fn update(&mut self, probes: &[(u64, f32)]) -> f32 {
        if probes.is_empty() {
            return self.eps;
        }
        let q = probes.len() as f64;
        let mut sum = 0.0f64;
        for &(_, g) in probes {
            sum += g as f64;
        }
        let mean = sum / q;
        let mut var = 0.0f64;
        for &(_, g) in probes {
            let d = g as f64 - mean;
            var += d * d;
        }
        var /= q;
        let spread = var.sqrt();
        let r = spread / (mean.abs() + spread + 1e-30);
        let factor = self.cfg.anneal as f64 + self.cfg.gain as f64 * r;
        let mut next = (self.eps as f64 * factor) as f32;
        next = next.clamp(self.lo, self.hi);
        if let Some(floor) = self.floor {
            if next < floor {
                if !self.floor_warned {
                    self.floor_warned = true;
                    eprintln!(
                        "warning: adapted ε = {next:.3e} fell below the bf16 \
                         ε floor mean|θ|/256 = {floor:.3e}; clamping — the \
                         bf16 drift bounds (DESIGN.md §Precision) assume \
                         probes stay above the stored-codec rounding noise"
                    );
                }
                next = floor;
            }
        }
        self.eps = next;
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::toy_params;

    /// Quadratic loss with per-array curvature: L = Σ_i c_i ‖θ_i‖²/2.
    fn quad_loss(p: &ParamSet) -> Result<f32> {
        let cs = [1.0f32, 10.0];
        let mut l = 0.0;
        for i in 0..p.n_arrays() {
            l += 0.5 * cs[i % 2] * p.array(i).iter().map(|x| x * x).sum::<f32>();
        }
        Ok(l)
    }

    #[test]
    fn restores_params() {
        let mut p = toy_params(&[32, 32]);
        let orig = p.clone();
        let _ = estimate_with(&mut p, 17, 1e-3, quad_loss).unwrap();
        assert!(p.max_abs_diff(&orig) < 1e-6, "drift {}", p.max_abs_diff(&orig));
    }

    #[test]
    fn estimates_projected_gradient() {
        // for quadratic loss, zᵀ∇L = Σ c_i θ_iᵀ z_i; check against the
        // analytically recomputed projection
        let mut p = toy_params(&[64, 64]);
        let est = estimate_with(&mut p, 23, 1e-4, quad_loss).unwrap();
        // recompute projection via visit_z
        let mut proj = 0f64;
        let cs = [1.0f32, 10.0];
        p.visit_z(23, |i, z| {
            for (x, zv) in p.array(i).iter().zip(z) {
                proj += (cs[i % 2] * x * zv) as f64;
            }
        });
        assert!(
            (est.g_scale as f64 - proj).abs() < 0.05 * proj.abs().max(1.0),
            "spsa {} vs exact {}",
            est.g_scale,
            proj
        );
    }

    #[test]
    fn loss_reported_is_mean_of_probes() {
        let mut p = toy_params(&[16]);
        let est = estimate_with(&mut p, 5, 1e-3, quad_loss).unwrap();
        assert!((est.loss() - 0.5 * (est.loss_plus + est.loss_minus)).abs() < 1e-7);
        // close to the unperturbed loss
        let l0 = quad_loss(&p).unwrap();
        assert!((est.loss() - l0).abs() < 0.05 * l0);
    }

    #[test]
    fn failing_oracle_restores_params() {
        let mut p = toy_params(&[16]);
        let orig = p.clone();
        let mut calls = 0;
        let r = estimate_with(&mut p, 3, 1e-3, |_| {
            calls += 1;
            if calls == 2 {
                anyhow::bail!("boom")
            }
            Ok(1.0)
        });
        assert!(r.is_err());
        assert!(p.max_abs_diff(&orig) < 1e-6);
    }

    #[test]
    fn unrestored_leaves_theta_minus_eps_z() {
        let mut p = toy_params(&[48]);
        let orig = p.clone();
        let eps = 1e-3f32;
        let est = estimate_unrestored(&mut p, 11, eps, quad_loss).unwrap();
        // θ is exactly the −ε probe point: original + εz − 2εz
        let mut q = orig.clone();
        q.perturb_trainable(11, eps);
        q.perturb_trainable(11, -2.0 * eps);
        assert_eq!(p.flat(), q.flat());
        // owing restore: +εz brings θ back within ulp drift
        p.perturb_trainable(11, eps);
        assert!(p.max_abs_diff(&orig) < 1e-6, "drift {}", p.max_abs_diff(&orig));
        // the estimate itself is bitwise the restored variant's
        let mut r = orig.clone();
        let full = estimate_with(&mut r, 11, eps, quad_loss).unwrap();
        assert_eq!(est.g_scale, full.g_scale);
        assert_eq!(est.loss_plus, full.loss_plus);
        assert_eq!(est.loss_minus, full.loss_minus);
    }

    #[test]
    fn cached_unrestored_matches_seeded_unrestored() {
        let mut a = toy_params(&[100, 28]);
        let mut b = toy_params(&[100, 28]);
        let mut cache = crate::model::params::ZCache::default();
        let ea = estimate_unrestored(&mut a, 9, 1e-3, quad_loss).unwrap();
        let eb =
            estimate_cached_unrestored(&mut b, &mut cache, 9, 1e-3, quad_loss).unwrap();
        assert_eq!(ea.g_scale, eb.g_scale);
        assert_eq!(a.flat(), b.flat()); // both sit at θ − εz
        assert!(cache.is_filled());
    }

    #[test]
    fn cached_estimate_is_bit_identical_to_regeneration() {
        let mut p1 = toy_params(&[64, 32]);
        let mut p2 = toy_params(&[64, 32]);
        let mut cache = crate::model::params::ZCache::default();
        let a = estimate_with(&mut p1, 31, 1e-3, quad_loss).unwrap();
        let b = estimate_cached(&mut p2, &mut cache, 31, 1e-3, quad_loss).unwrap();
        assert_eq!(a.g_scale, b.g_scale);
        assert_eq!(a.loss_plus, b.loss_plus);
        assert_eq!(a.loss_minus, b.loss_minus);
        assert_eq!(p1.flat(), p2.flat()); // identical restore arithmetic
    }

    #[test]
    fn cached_estimate_respects_frozen_arrays() {
        let mut p = toy_params(&[16, 16]);
        p.train_mask[0] = false;
        let orig = p.clone();
        let mut cache = crate::model::params::ZCache::default();
        let _ = estimate_cached(&mut p, &mut cache, 5, 1e-3, quad_loss).unwrap();
        assert_eq!(p.array(0), orig.array(0));
        assert!(p.max_abs_diff(&orig) < 1e-6); // restored overall
    }

    #[test]
    fn preperturbed_matches_unrestored_probe_pair() {
        // starting from θ + εz, the preperturbed pair produces the exact
        // estimate of the classic pair and parks θ at the same −ε point
        let eps = 1e-3f32;
        let mut a = toy_params(&[100, 28]);
        let mut b = toy_params(&[100, 28]);
        let ea = estimate_unrestored(&mut a, 13, eps, quad_loss).unwrap();
        b.perturb_trainable(13, eps); // the prologue / previous prefetch
        let eb = estimate_preperturbed(&mut b, 13, eps, quad_loss).unwrap();
        assert_eq!(ea.g_scale, eb.g_scale);
        assert_eq!(ea.loss_plus, eb.loss_plus);
        assert_eq!(ea.loss_minus, eb.loss_minus);
        assert_eq!(a.flat(), b.flat());
    }

    #[test]
    fn cached_preperturbed_matches_seeded_preperturbed() {
        let eps = 1e-3f32;
        let mut a = toy_params(&[64, 40]);
        let mut b = a.clone();
        a.perturb_trainable(21, eps);
        let mut cache = crate::model::params::ZCache::default();
        b.perturb_fill_cache(&mut cache, 21, eps);
        assert_eq!(a.flat(), b.flat());
        let ea = estimate_preperturbed(&mut a, 21, eps, quad_loss).unwrap();
        let eb = estimate_cached_preperturbed(&mut b, &cache, 21, eps, quad_loss).unwrap();
        assert_eq!(ea.g_scale, eb.g_scale);
        assert_eq!(a.flat(), b.flat());
    }

    #[test]
    fn cached_preperturbed_rejects_wrong_seed() {
        let eps = 1e-3f32;
        let mut p = toy_params(&[32]);
        let mut cache = crate::model::params::ZCache::default();
        p.perturb_fill_cache(&mut cache, 5, eps);
        let before = p.clone();
        // asking for seed 6 against a seed-5 cache is a recoverable error
        // and must not touch θ
        assert!(estimate_cached_preperturbed(&mut p, &cache, 6, eps, quad_loss).is_err());
        assert_eq!(p.flat(), before.flat());
    }

    #[test]
    fn preperturbed_failing_oracle_restores_params() {
        let eps = 1e-3f32;
        for fail_at in [1usize, 2] {
            let mut p = toy_params(&[48]);
            let orig = p.clone();
            p.perturb_trainable(3, eps);
            let mut calls = 0;
            let r = estimate_preperturbed(&mut p, 3, eps, |_| {
                calls += 1;
                if calls == fail_at {
                    anyhow::bail!("boom")
                }
                Ok(1.0)
            });
            assert!(r.is_err());
            assert!(p.max_abs_diff(&orig) < 1e-6, "fail_at {fail_at}");
        }
    }

    #[test]
    fn staged_preperturbed_matches_monolithic_and_executes_from_stage() {
        use crate::model::params::{TileSpec, ZCache};
        use crate::runtime::{stream_theta, HostThetaStage};
        let eps = 1e-3f32;
        let flatq = |p: &ParamSet| Ok(p.flat().iter().map(|x| x * x).sum::<f32>());
        for cached in [true, false] {
            for tiles in [TileSpec::by_shards(1), TileSpec::whole_arena()] {
                // monolithic reference
                let mut a = toy_params(&[100, 28]);
                let mut ca = ZCache::default();
                a.perturb_fill_cache(&mut ca, 21, eps);
                let ea = if cached {
                    estimate_cached_preperturbed(&mut a, &ca, 21, eps, flatq).unwrap()
                } else {
                    estimate_preperturbed(&mut a, 21, eps, flatq).unwrap()
                };

                // staged path: every loss reads the STAGED bytes, proving
                // the sink holds exactly θ at both probe points
                let mut b = toy_params(&[100, 28]);
                let mut cb = ZCache::default();
                b.perturb_fill_cache(&mut cb, 21, eps);
                let mut sink = HostThetaStage::default();
                stream_theta(&b, tiles, &mut sink).unwrap();
                let cache = if cached { Some(&cb) } else { None };
                let eb = estimate_staged_preperturbed(
                    &mut b, cache, 21, eps, tiles, &mut sink,
                    |s: &mut HostThetaStage| Ok(s.values().iter().map(|x| x * x).sum::<f32>()),
                )
                .unwrap();
                assert_eq!(ea.g_scale, eb.g_scale, "cached {cached}");
                assert_eq!(ea.loss_plus, eb.loss_plus);
                assert_eq!(ea.loss_minus, eb.loss_minus);
                assert_eq!(a.flat(), b.flat()); // both parked at θ − εz
            }
        }
    }

    #[test]
    fn staged_preperturbed_failing_exec_restores_params() {
        use crate::model::params::{TileSpec, ZCache};
        use crate::runtime::{stream_theta, HostThetaStage};
        let eps = 1e-3f32;
        for fail_at in [1usize, 2] {
            let mut p = toy_params(&[48]);
            let orig = p.clone();
            let mut cache = ZCache::default();
            p.perturb_fill_cache(&mut cache, 3, eps);
            let mut sink = HostThetaStage::default();
            stream_theta(&p, TileSpec::by_shards(1), &mut sink).unwrap();
            let mut calls = 0;
            let r = estimate_staged_preperturbed(
                &mut p, Some(&cache), 3, eps, TileSpec::by_shards(1), &mut sink,
                |_s: &mut HostThetaStage| {
                    calls += 1;
                    if calls == fail_at {
                        anyhow::bail!("boom")
                    }
                    Ok(1.0)
                },
            );
            assert!(r.is_err());
            assert!(p.max_abs_diff(&orig) < 1e-6, "fail_at {fail_at}");
        }
    }

    #[test]
    fn staged_preperturbed_rejects_wrong_seed() {
        use crate::model::params::{TileSpec, ZCache};
        use crate::runtime::{stream_theta, HostThetaStage};
        let eps = 1e-3f32;
        let mut p = toy_params(&[32]);
        let mut cache = ZCache::default();
        p.perturb_fill_cache(&mut cache, 5, eps);
        let mut sink = HostThetaStage::default();
        stream_theta(&p, TileSpec::whole_arena(), &mut sink).unwrap();
        let before = p.clone();
        let r = estimate_staged_preperturbed(
            &mut p, Some(&cache), 6, eps, TileSpec::whole_arena(), &mut sink,
            |_s: &mut HostThetaStage| Ok(1.0),
        );
        assert!(r.is_err());
        assert_eq!(p.flat(), before.flat());
    }

    #[test]
    fn different_seeds_give_different_estimates() {
        let mut p = toy_params(&[64]);
        let a = estimate_with(&mut p, 1, 1e-3, quad_loss).unwrap();
        let b = estimate_with(&mut p, 2, 1e-3, quad_loss).unwrap();
        assert_ne!(a.g_scale, b.g_scale);
    }

    #[test]
    fn multi_pipeline_matches_sequential_probes_on_scripted_oracle() {
        // scripted oracle: losses come off a list, independent of θ, so
        // the transition-chain pipeline and the naive perturb/eval/restore
        // loop see identical values — g must match bitwise, and both
        // walks must return θ to the pristine point (up to re-add drift).
        let eps = 1e-3f32;
        let script = [2.0f32, 1.5, 3.25, 0.75, 1.0];
        for q in [1usize, 2, 4] {
            let mut a = toy_params(&[100, 28]);
            let orig = a.clone();
            a.perturb_trainable(probe_seed(40, 0), eps); // prologue prefetch
            let mut k = 0usize;
            let est = estimate_multi_preperturbed(&mut a, 40, q, eps, |_| {
                let l = script[k.min(q)]; // probes 0..q, then the baseline
                k += 1;
                Ok(l)
            })
            .unwrap();
            assert_eq!(k, q + 1, "q+1 oracle calls for q probes");
            assert_eq!(est.loss_base, script[q]);
            assert_eq!(est.losses, script[..q].to_vec());

            // naive reference: q sequential one-sided estimates sharing
            // the same scripted baseline
            let mut b = orig.clone();
            for (i, &(seed, g)) in est.probes.iter().enumerate() {
                assert_eq!(seed, probe_seed(40, i));
                assert_eq!(g, (script[i] - script[q]) / eps, "probe {i}");
                b.perturb_trainable(seed, eps);
                b.perturb_trainable(seed, -eps);
            }
            let avg = est.averaged_probes();
            for (&(_, g), &(_, ga)) in est.probes.iter().zip(&avg) {
                assert_eq!(ga, g / q as f32);
            }
            assert!(a.max_abs_diff(&orig) < 1e-5, "pipeline drift q={q}");
            assert!(b.max_abs_diff(&orig) < 1e-5, "naive drift q={q}");
        }
    }

    #[test]
    fn multi_probe_losses_are_the_real_probe_points() {
        // on a real oracle, probe 0's loss is bitwise the loss at the
        // prefetched θ + εz₀, and the baseline sits within drift of L(θ)
        let eps = 1e-3f32;
        let mut p = toy_params(&[64, 40]);
        let orig = p.clone();
        p.perturb_trainable(probe_seed(21, 0), eps);
        let lp = quad_loss(&p).unwrap(); // loss at the armed probe-0 point
        let est = estimate_multi_preperturbed(&mut p, 21, 3, eps, quad_loss).unwrap();
        assert_eq!(est.losses[0], lp);
        let l0 = quad_loss(&orig).unwrap();
        assert!((est.loss() - l0).abs() < 0.01 * l0.max(1.0));
        assert!(p.max_abs_diff(&orig) < 1e-5);
        // each one-sided projection matches the quadratic's exact value
        // zᵢᵀ∇L + (ε/2)·zᵢᵀHzᵢ (the O(ε) curvature bias a two-point
        // estimate would cancel)
        let cs = [1.0f32, 10.0];
        for (i, &(seed, g)) in est.probes.iter().enumerate() {
            let mut proj = 0f64;
            let mut zhz = 0f64;
            orig.visit_z(seed, |ai, z| {
                for (x, zv) in orig.array(ai).iter().zip(z) {
                    proj += (cs[ai % 2] * x * zv) as f64;
                    zhz += (cs[ai % 2] * zv * zv) as f64;
                }
            });
            let expect = proj + 0.5 * eps as f64 * zhz;
            assert!(
                (g as f64 - expect).abs() < 0.05 * expect.abs().max(1.0),
                "probe {i}: one-sided {g} vs exact {expect}"
            );
        }
    }

    #[test]
    fn multi_nonfinite_loss_aborts_with_context_and_restores() {
        let eps = 1e-3f32;
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for fail_at in [0usize, 1, 2] {
                // fail_at 0/1 hit probes, 2 hits the baseline (q = 2)
                let mut p = toy_params(&[48]);
                let orig = p.clone();
                p.perturb_trainable(probe_seed(7, 0), eps);
                let mut calls = 0usize;
                let r = estimate_multi_preperturbed(&mut p, 7, 2, eps, |_| {
                    let l = if calls == fail_at { bad } else { 1.0 };
                    calls += 1;
                    Ok(l)
                });
                let err = format!("{:#}", r.unwrap_err());
                assert!(err.contains("non-finite"), "{err}");
                assert!(
                    p.max_abs_diff(&orig) < 1e-5,
                    "bad {bad}, fail_at {fail_at}: drift {}",
                    p.max_abs_diff(&orig)
                );
            }
        }
    }

    #[test]
    fn multi_failing_oracle_restores_and_names_the_probe() {
        let eps = 1e-3f32;
        for fail_at in [0usize, 1, 2] {
            let mut p = toy_params(&[48]);
            let orig = p.clone();
            p.perturb_trainable(probe_seed(3, 0), eps);
            let mut calls = 0usize;
            let r = estimate_multi_preperturbed(&mut p, 3, 2, eps, |_| {
                if calls == fail_at {
                    anyhow::bail!("boom")
                }
                calls += 1;
                Ok(1.0)
            });
            let err = format!("{:#}", r.unwrap_err());
            assert!(err.contains("boom"), "{err}");
            let tag = if fail_at == 2 { "baseline" } else { "probe" };
            assert!(err.contains(tag), "fail_at {fail_at}: {err}");
            assert!(p.max_abs_diff(&orig) < 1e-5, "fail_at {fail_at}");
        }
    }

    #[test]
    fn multi_cached_rejects_wrong_seed_and_accepts_right_one() {
        let eps = 1e-3f32;
        let mut p = toy_params(&[32]);
        let mut cache = crate::model::params::ZCache::default();
        p.perturb_fill_cache(&mut cache, 5, eps);
        let before = p.clone();
        let r = estimate_multi_cached_preperturbed(&mut p, &cache, 6, 2, eps, quad_loss);
        assert!(r.is_err());
        assert_eq!(p.flat(), before.flat());
        let est =
            estimate_multi_cached_preperturbed(&mut p, &cache, 5, 2, eps, quad_loss)
                .unwrap();
        assert_eq!(est.probes.len(), 2);
    }

    #[test]
    fn multi_rejects_zero_probes() {
        let mut p = toy_params(&[16]);
        assert!(estimate_multi_preperturbed(&mut p, 1, 0, 1e-3, quad_loss).is_err());
    }

    #[test]
    fn fold_partial_losses_matches_an_f64_left_fold() {
        assert_eq!(fold_partial_losses(std::iter::empty()), 0.0);
        let parts = [1.25f64, -0.5, 3.0e-7, 1.0e9, -1.0e9];
        let mut acc = 0.0f64;
        for p in parts {
            acc += p;
        }
        let folded = fold_partial_losses(parts.iter().copied());
        assert_eq!(folded.to_bits(), (acc as f32).to_bits());
        // grouping shards into spans is concatenation — same fold
        let grouped =
            fold_partial_losses(parts[..2].iter().chain(&parts[2..]).copied());
        assert_eq!(folded.to_bits(), grouped.to_bits());
    }

    /// Scripted oracle: returns `bad` on call number `fail_at`, else a
    /// benign constant.
    fn scripted(bad: f32, fail_at: usize) -> impl FnMut(&ParamSet) -> Result<f32> {
        let mut calls = 0usize;
        move |_| {
            let l = if calls == fail_at { bad } else { 1.0 };
            calls += 1;
            Ok(l)
        }
    }

    fn assert_nonfinite_abort(err: anyhow::Error, fail_at: usize, seed: u64) {
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite loss"), "{msg}");
        let probe = if fail_at == 0 { "+ε probe" } else { "−ε probe" };
        assert!(msg.contains(probe), "fail_at {fail_at}: {msg}");
        assert!(msg.contains(&format!("step seed {seed}")), "{msg}");
    }

    #[test]
    fn nonfinite_loss_aborts_seeded_estimators_after_restoring() {
        let eps = 1e-3f32;
        let seed = 11u64;
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for fail_at in [0usize, 1] {
                // seeded, unrestored protocol
                let mut p = toy_params(&[48, 16]);
                let orig = p.clone();
                let r = estimate_unrestored(&mut p, seed, eps, scripted(bad, fail_at));
                assert_nonfinite_abort(r.unwrap_err(), fail_at, seed);
                assert!(p.max_abs_diff(&orig) < 1e-5, "fail_at {fail_at}");

                // classic full-cycle wrapper delegates to the same checks
                let mut p = toy_params(&[48, 16]);
                let orig = p.clone();
                let r = estimate_with(&mut p, seed, eps, scripted(bad, fail_at));
                assert_nonfinite_abort(r.unwrap_err(), fail_at, seed);
                assert!(p.max_abs_diff(&orig) < 1e-5, "fail_at {fail_at}");

                // prefetch protocol: θ arrives pre-perturbed
                let mut p = toy_params(&[48, 16]);
                let orig = p.clone();
                p.perturb_trainable(seed, eps);
                let r = estimate_preperturbed(&mut p, seed, eps, scripted(bad, fail_at));
                assert_nonfinite_abort(r.unwrap_err(), fail_at, seed);
                assert!(p.max_abs_diff(&orig) < 1e-5, "fail_at {fail_at}");
            }
        }
    }

    #[test]
    fn nonfinite_loss_aborts_cached_estimators_after_restoring() {
        let eps = 1e-3f32;
        let seed = 12u64;
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for fail_at in [0usize, 1] {
                let mut p = toy_params(&[48, 16]);
                let orig = p.clone();
                let mut cache = crate::model::params::ZCache::default();
                let r = estimate_cached_unrestored(
                    &mut p, &mut cache, seed, eps, scripted(bad, fail_at),
                );
                assert_nonfinite_abort(r.unwrap_err(), fail_at, seed);
                assert!(p.max_abs_diff(&orig) < 1e-5, "fail_at {fail_at}");

                let mut p = toy_params(&[48, 16]);
                let orig = p.clone();
                let mut cache = crate::model::params::ZCache::default();
                p.perturb_fill_cache(&mut cache, seed, eps);
                let r = estimate_cached_preperturbed(
                    &mut p, &cache, seed, eps, scripted(bad, fail_at),
                );
                assert_nonfinite_abort(r.unwrap_err(), fail_at, seed);
                assert!(p.max_abs_diff(&orig) < 1e-5, "fail_at {fail_at}");
            }
        }
    }

    #[test]
    fn nonfinite_loss_aborts_staged_estimator_after_restoring() {
        use crate::model::params::TileSpec;
        use crate::runtime::{stream_theta, HostThetaStage};
        let eps = 1e-3f32;
        let seed = 13u64;
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for fail_at in [0usize, 1] {
                for cached in [false, true] {
                    let mut p = toy_params(&[48, 16]);
                    let orig = p.clone();
                    let mut cache = crate::model::params::ZCache::default();
                    if cached {
                        p.perturb_fill_cache(&mut cache, seed, eps);
                    } else {
                        p.perturb_trainable(seed, eps);
                    }
                    let mut sink = HostThetaStage::default();
                    stream_theta(&p, TileSpec::by_shards(1), &mut sink).unwrap();
                    let mut calls = 0usize;
                    let r = estimate_staged_preperturbed(
                        &mut p,
                        cached.then_some(&cache),
                        seed,
                        eps,
                        TileSpec::by_shards(1),
                        &mut sink,
                        |_| {
                            let l = if calls == fail_at { bad } else { 1.0 };
                            calls += 1;
                            Ok(l)
                        },
                    );
                    assert_nonfinite_abort(r.unwrap_err(), fail_at, seed);
                    assert!(
                        p.max_abs_diff(&orig) < 1e-5,
                        "fail_at {fail_at} cached {cached}"
                    );
                }
            }
        }
    }

    #[test]
    fn eps_schedule_q1_is_pure_geometric_annealing() {
        // spread of a single probe is identically 0 → factor == anneal,
        // bit for bit, regardless of the probe scalar's value
        let cfg = EpsAdaptConfig::default();
        let mut sched = EpsSchedule::new(cfg, 1e-3, None).unwrap();
        let mut expect = 1e-3f32;
        for g in [0.25f32, -3.0, 1e4, 0.0] {
            let got = sched.update(&[(7, g)]);
            expect = (expect as f64 * cfg.anneal as f64) as f32;
            assert_eq!(got.to_bits(), expect.to_bits(), "g = {g}");
        }
    }

    #[test]
    fn eps_schedule_is_a_pure_function_of_its_inputs() {
        let cfg = EpsAdaptConfig { gain: 0.3, ..EpsAdaptConfig::default() };
        let probes: Vec<Vec<(u64, f32)>> = (0..20)
            .map(|s| (0..4).map(|i| (i, ((s * 4 + i) as f32).sin())).collect())
            .collect();
        let run = || {
            let mut sched = EpsSchedule::new(cfg, 2e-3, None).unwrap();
            probes.iter().map(|p| sched.update(p).to_bits()).collect::<Vec<u32>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn eps_schedule_clamps_to_the_ratio_band() {
        // gain large enough that factor > 1 whenever the spread dominates
        let cfg = EpsAdaptConfig { anneal: 0.5, gain: 4.0, ..EpsAdaptConfig::default() };
        let eps0 = 1e-3f32;
        let mut sched = EpsSchedule::new(cfg, eps0, None).unwrap();
        // zero-mean, high-spread probes → factor ≈ 4.5 → hits the hi clamp
        let noisy = [(1u64, 1.0f32), (2, -1.0)];
        for _ in 0..10 {
            sched.update(&noisy);
        }
        assert_eq!(sched.eps().to_bits(), (cfg.max_ratio * eps0).to_bits());
        // single probe → pure 0.5× annealing → hits the lo clamp
        for _ in 0..20 {
            sched.update(&[(3, 1.0)]);
        }
        assert_eq!(sched.eps().to_bits(), (cfg.min_ratio * eps0).to_bits());
    }

    #[test]
    fn eps_schedule_respects_the_bf16_floor_when_given_one() {
        let cfg = EpsAdaptConfig { anneal: 0.5, gain: 0.0, ..EpsAdaptConfig::default() };
        let eps0 = 1e-3f32;
        let floor = 4e-4f32;
        // with the floor: annealing stops exactly at it
        let mut floored = EpsSchedule::new(cfg, eps0, Some(floor)).unwrap();
        for _ in 0..8 {
            floored.update(&[(1, 0.5)]);
        }
        assert_eq!(floored.eps().to_bits(), floor.to_bits());
        // without it (f32 mode): the same schedule anneals straight past,
        // down to the ratio band's lower clamp
        let mut free = EpsSchedule::new(cfg, eps0, None).unwrap();
        for _ in 0..8 {
            free.update(&[(1, 0.5)]);
        }
        assert!(free.eps() < floor);
        assert_eq!(free.eps().to_bits(), (cfg.min_ratio * eps0).to_bits());
    }

    #[test]
    fn eps_adapt_config_validation_names_the_bad_field() {
        let bad = [
            (EpsAdaptConfig { anneal: 0.0, ..Default::default() }, "anneal"),
            (EpsAdaptConfig { anneal: f32::NAN, ..Default::default() }, "anneal"),
            (EpsAdaptConfig { gain: -0.1, ..Default::default() }, "gain"),
            (EpsAdaptConfig { min_ratio: 0.0, ..Default::default() }, "min-ratio"),
            (
                EpsAdaptConfig { min_ratio: 2.0, max_ratio: 1.0, ..Default::default() },
                "max-ratio",
            ),
        ];
        for (cfg, field) in bad {
            let msg = format!("{:#}", cfg.validate().unwrap_err());
            assert!(msg.contains(field), "{msg} should name {field}");
        }
        EpsAdaptConfig::default().validate().unwrap();
    }
}
