//! SPSA two-point gradient estimation with MeZO's seeded in-place protocol.
//!
//! For loss L and perturbation scale ε (paper §2.1):
//!
//! ```text
//! θ ← θ + εz ;  L⁺ = L(θ)
//! θ ← θ − 2εz;  L⁻ = L(θ)
//! θ ← θ + εz              (restore)
//! g_scale = (L⁺ − L⁻) / 2ε        — the projected gradient  zᵀ∇L
//! ```
//!
//! `z ~ N(0, I)` is regenerated from the step seed at every use and never
//! materialised, so the extra memory is zero — the property that lets MeZO
//! (and HELENE on top of it) train with inference-level memory.
//!
//! The `*_unrestored` variants stop after L⁻, leaving `θ − εz`: the trainer
//! then calls `Optimizer::step_zo_fused`, which folds the `+εz` restore
//! into the optimizer's update sweep — one fewer full pass over the arena
//! per step with bit-identical arithmetic (§Perf, property-tested in
//! `tests/shard_determinism.rs`).
//!
//! The `*_preperturbed` variants additionally assume θ **arrives at
//! `θ + εz`** — perturbed by the previous step's fused prefetch sweep
//! (`Optimizer::step_zo_fused_prefetch`) or by a prologue perturb — so the
//! opening `+εz` sweep disappears too: one probe pair costs a single
//! `−2εz` arena sweep, and the steady-state step is two sweeps total
//! (`train::ZoProtocol`).
//!
//! The estimator is generic over the loss oracle so the same code drives
//! the PJRT model runner, the 2-D toy problems, and the unit tests.

use anyhow::Result;

use crate::model::params::ParamSet;

/// One SPSA measurement.
#[derive(Clone, Copy, Debug)]
pub struct SpsaEstimate {
    /// zᵀ∇L estimate: feed to `Optimizer::step_zo` together with `seed`.
    pub g_scale: f32,
    /// seed that regenerates this step's z
    pub seed: u64,
    /// loss at the +ε probe point
    pub loss_plus: f32,
    /// loss at the −ε probe point
    pub loss_minus: f32,
}

impl SpsaEstimate {
    /// The loss value reported for this step (mean of the two probes —
    /// an unbiased estimate of L(θ) to O(ε²)).
    pub fn loss(&self) -> f32 {
        0.5 * (self.loss_plus + self.loss_minus)
    }
}

/// Cached probe pair **without the restore pass**: on success `params` is
/// left at `θ − εz` and the caller owes a `+εz` restore — normally folded
/// into the optimizer update via `Optimizer::step_zo_fused`, which turns
/// restore + update into a single arena sweep (§Perf). The z draws live in
/// `cache` for the −2ε pass and the fused step. On error `params` IS fully
/// restored before returning.
pub fn estimate_cached_unrestored<F>(
    params: &mut ParamSet,
    cache: &mut crate::model::params::ZCache,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    params.perturb_fill_cache(cache, seed, eps);
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, -eps);
            return Err(e);
        }
    };
    params.perturb_from_cache(cache, seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, eps);
            return Err(e);
        }
    };
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Cached variant of [`estimate_with`]: the z draws are generated once into
/// `cache` (one RNG pass) and reused for the −2ε and restore passes —
/// identical arithmetic, ~2 RNG passes saved per step (§Perf). Costs one
/// trainable-sized scratch buffer (`TrainConfig::cache_z`).
pub fn estimate_cached<F>(
    params: &mut ParamSet,
    cache: &mut crate::model::params::ZCache,
    seed: u64,
    eps: f32,
    loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    let est = estimate_cached_unrestored(params, cache, seed, eps, loss_fn)?;
    params.perturb_from_cache(cache, seed, eps);
    Ok(est)
}

/// Probe pair for the cross-step prefetch protocol: `params` must arrive
/// **already at `θ + εz(seed)`** (left there by the previous step's fused
/// prefetch sweep, or by a prologue perturb at a run boundary). L⁺ is
/// measured immediately, one `−2εz` sweep reaches the L⁻ point, and on
/// success `params` is left at `θ − εz` with the `+εz` restore owed to the
/// optimizer step — two probe losses for a single arena sweep. On error
/// `params` is returned to the unperturbed θ (up to the usual f32 re-add
/// drift) and the caller must abandon the pipeline.
pub fn estimate_preperturbed<F>(
    params: &mut ParamSet,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, -eps); // unwind the prefetch
            return Err(e);
        }
    };
    params.perturb_trainable(seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, eps);
            return Err(e);
        }
    };
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Cached flavour of [`estimate_preperturbed`]: the draws of `seed` must
/// already sit in `cache` — captured by the previous step's fused prefetch
/// sweep or by the prologue `perturb_fill_cache`. The seed key is checked
/// up front (a mis-rotated buffer is a recoverable error, caught before
/// anything touches θ); the `−2εz` sweep then reuses the cached draws.
pub fn estimate_cached_preperturbed<F>(
    params: &mut ParamSet,
    cache: &crate::model::params::ZCache,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    anyhow::ensure!(
        cache.matches_seed(params, seed),
        "z-cache does not hold the draws of seed {seed} for this layout \
         (holds seed {}, filled: {})",
        cache.seed(),
        cache.is_filled(),
    );
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, -eps);
            return Err(e);
        }
    };
    params.perturb_from_cache(cache, seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_from_cache(cache, seed, eps);
            return Err(e);
        }
    };
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Tiled flavour of the pre-perturbed probe pair (DESIGN.md §Runtime,
/// tiled θ-streaming): θ must arrive at `θ + εz(seed)` **with that
/// generation already staged in `sink`** (by the previous step's staged
/// fused sweep or a staged prologue). L⁺ executes from the staged
/// generation via `exec`; the `−2εz` sweep then runs **tile-by-tile**,
/// streaming each tile into `sink` as soon as it is produced — on an
/// async upload path tile *t+1*'s sweep overlaps tile *t*'s upload, and
/// on the host the stage copy reads the cache-hot tile — and L⁻ executes
/// from the freshly staged `θ − εz`. `cache` selects the cached-draw or
/// seeded-regeneration sweep (`TrainConfig::cache_z`); arithmetic is
/// bitwise the monolithic [`estimate_cached_preperturbed`] /
/// [`estimate_preperturbed`] pair for any tile size.
///
/// On an `exec` error θ is restored to the unperturbed point exactly like
/// the monolithic estimators; a `sink` error aborts mid-sweep and the
/// caller must abandon the run (same contract as a failed fused sweep).
pub fn estimate_staged_preperturbed<S, F>(
    params: &mut ParamSet,
    cache: Option<&crate::model::params::ZCache>,
    seed: u64,
    eps: f32,
    tiles: crate::model::params::TileSpec,
    sink: &mut S,
    mut exec: F,
) -> Result<SpsaEstimate>
where
    S: crate::runtime::StagedThetaSink + ?Sized,
    F: FnMut(&mut S) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    if let Some(c) = cache {
        anyhow::ensure!(
            c.matches_seed(params, seed),
            "z-cache does not hold the draws of seed {seed} for this layout \
             (holds seed {}, filled: {})",
            c.seed(),
            c.is_filled(),
        );
    }
    let loss_plus = match exec(sink) {
        Ok(l) => l,
        Err(e) => {
            match cache {
                Some(c) => params.perturb_from_cache(c, seed, -eps),
                None => params.perturb_trainable(seed, -eps),
            }
            return Err(e);
        }
    };
    sink.begin_theta(params)?;
    for tile in params.theta_tiles(tiles) {
        match cache {
            Some(c) => params.perturb_tile_from_cache(&tile, c, seed, -2.0 * eps),
            None => params.perturb_tile(&tile, seed, -2.0 * eps),
        }
        sink.stage_tile(&tile, &params.tile_f32(&tile))?;
    }
    sink.finish_theta()?;
    let loss_minus = match exec(sink) {
        Ok(l) => l,
        Err(e) => {
            match cache {
                Some(c) => params.perturb_from_cache(c, seed, eps),
                None => params.perturb_trainable(seed, eps),
            }
            return Err(e);
        }
    };
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Probe pair **without the restore pass** (seeded-regeneration flavour of
/// [`estimate_cached_unrestored`]): on success `params` is left at
/// `θ − εz`; the caller owes the `+εz` restore (`Optimizer::step_zo_fused`
/// folds it into the update sweep). On error `params` IS fully restored.
pub fn estimate_unrestored<F>(
    params: &mut ParamSet,
    seed: u64,
    eps: f32,
    mut loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    debug_assert!(eps > 0.0);
    params.perturb_trainable(seed, eps);
    let loss_plus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, -eps); // restore before bailing
            return Err(e);
        }
    };
    params.perturb_trainable(seed, -2.0 * eps);
    let loss_minus = match loss_fn(params) {
        Ok(l) => l,
        Err(e) => {
            params.perturb_trainable(seed, eps);
            return Err(e);
        }
    };
    Ok(SpsaEstimate {
        g_scale: (loss_plus - loss_minus) / (2.0 * eps),
        seed,
        loss_plus,
        loss_minus,
    })
}

/// Run the perturb → probe → restore cycle against an arbitrary loss oracle.
/// On success `params` is restored (up to f32 re-add drift, see `ParamSet`).
pub fn estimate_with<F>(
    params: &mut ParamSet,
    seed: u64,
    eps: f32,
    loss_fn: F,
) -> Result<SpsaEstimate>
where
    F: FnMut(&ParamSet) -> Result<f32>,
{
    let est = estimate_unrestored(params, seed, eps, loss_fn)?;
    params.perturb_trainable(seed, eps);
    Ok(est)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::toy_params;

    /// Quadratic loss with per-array curvature: L = Σ_i c_i ‖θ_i‖²/2.
    fn quad_loss(p: &ParamSet) -> Result<f32> {
        let cs = [1.0f32, 10.0];
        let mut l = 0.0;
        for i in 0..p.n_arrays() {
            l += 0.5 * cs[i % 2] * p.array(i).iter().map(|x| x * x).sum::<f32>();
        }
        Ok(l)
    }

    #[test]
    fn restores_params() {
        let mut p = toy_params(&[32, 32]);
        let orig = p.clone();
        let _ = estimate_with(&mut p, 17, 1e-3, quad_loss).unwrap();
        assert!(p.max_abs_diff(&orig) < 1e-6, "drift {}", p.max_abs_diff(&orig));
    }

    #[test]
    fn estimates_projected_gradient() {
        // for quadratic loss, zᵀ∇L = Σ c_i θ_iᵀ z_i; check against the
        // analytically recomputed projection
        let mut p = toy_params(&[64, 64]);
        let est = estimate_with(&mut p, 23, 1e-4, quad_loss).unwrap();
        // recompute projection via visit_z
        let mut proj = 0f64;
        let cs = [1.0f32, 10.0];
        p.visit_z(23, |i, z| {
            for (x, zv) in p.array(i).iter().zip(z) {
                proj += (cs[i % 2] * x * zv) as f64;
            }
        });
        assert!(
            (est.g_scale as f64 - proj).abs() < 0.05 * proj.abs().max(1.0),
            "spsa {} vs exact {}",
            est.g_scale,
            proj
        );
    }

    #[test]
    fn loss_reported_is_mean_of_probes() {
        let mut p = toy_params(&[16]);
        let est = estimate_with(&mut p, 5, 1e-3, quad_loss).unwrap();
        assert!((est.loss() - 0.5 * (est.loss_plus + est.loss_minus)).abs() < 1e-7);
        // close to the unperturbed loss
        let l0 = quad_loss(&p).unwrap();
        assert!((est.loss() - l0).abs() < 0.05 * l0);
    }

    #[test]
    fn failing_oracle_restores_params() {
        let mut p = toy_params(&[16]);
        let orig = p.clone();
        let mut calls = 0;
        let r = estimate_with(&mut p, 3, 1e-3, |_| {
            calls += 1;
            if calls == 2 {
                anyhow::bail!("boom")
            }
            Ok(1.0)
        });
        assert!(r.is_err());
        assert!(p.max_abs_diff(&orig) < 1e-6);
    }

    #[test]
    fn unrestored_leaves_theta_minus_eps_z() {
        let mut p = toy_params(&[48]);
        let orig = p.clone();
        let eps = 1e-3f32;
        let est = estimate_unrestored(&mut p, 11, eps, quad_loss).unwrap();
        // θ is exactly the −ε probe point: original + εz − 2εz
        let mut q = orig.clone();
        q.perturb_trainable(11, eps);
        q.perturb_trainable(11, -2.0 * eps);
        assert_eq!(p.flat(), q.flat());
        // owing restore: +εz brings θ back within ulp drift
        p.perturb_trainable(11, eps);
        assert!(p.max_abs_diff(&orig) < 1e-6, "drift {}", p.max_abs_diff(&orig));
        // the estimate itself is bitwise the restored variant's
        let mut r = orig.clone();
        let full = estimate_with(&mut r, 11, eps, quad_loss).unwrap();
        assert_eq!(est.g_scale, full.g_scale);
        assert_eq!(est.loss_plus, full.loss_plus);
        assert_eq!(est.loss_minus, full.loss_minus);
    }

    #[test]
    fn cached_unrestored_matches_seeded_unrestored() {
        let mut a = toy_params(&[100, 28]);
        let mut b = toy_params(&[100, 28]);
        let mut cache = crate::model::params::ZCache::default();
        let ea = estimate_unrestored(&mut a, 9, 1e-3, quad_loss).unwrap();
        let eb =
            estimate_cached_unrestored(&mut b, &mut cache, 9, 1e-3, quad_loss).unwrap();
        assert_eq!(ea.g_scale, eb.g_scale);
        assert_eq!(a.flat(), b.flat()); // both sit at θ − εz
        assert!(cache.is_filled());
    }

    #[test]
    fn cached_estimate_is_bit_identical_to_regeneration() {
        let mut p1 = toy_params(&[64, 32]);
        let mut p2 = toy_params(&[64, 32]);
        let mut cache = crate::model::params::ZCache::default();
        let a = estimate_with(&mut p1, 31, 1e-3, quad_loss).unwrap();
        let b = estimate_cached(&mut p2, &mut cache, 31, 1e-3, quad_loss).unwrap();
        assert_eq!(a.g_scale, b.g_scale);
        assert_eq!(a.loss_plus, b.loss_plus);
        assert_eq!(a.loss_minus, b.loss_minus);
        assert_eq!(p1.flat(), p2.flat()); // identical restore arithmetic
    }

    #[test]
    fn cached_estimate_respects_frozen_arrays() {
        let mut p = toy_params(&[16, 16]);
        p.train_mask[0] = false;
        let orig = p.clone();
        let mut cache = crate::model::params::ZCache::default();
        let _ = estimate_cached(&mut p, &mut cache, 5, 1e-3, quad_loss).unwrap();
        assert_eq!(p.array(0), orig.array(0));
        assert!(p.max_abs_diff(&orig) < 1e-6); // restored overall
    }

    #[test]
    fn preperturbed_matches_unrestored_probe_pair() {
        // starting from θ + εz, the preperturbed pair produces the exact
        // estimate of the classic pair and parks θ at the same −ε point
        let eps = 1e-3f32;
        let mut a = toy_params(&[100, 28]);
        let mut b = toy_params(&[100, 28]);
        let ea = estimate_unrestored(&mut a, 13, eps, quad_loss).unwrap();
        b.perturb_trainable(13, eps); // the prologue / previous prefetch
        let eb = estimate_preperturbed(&mut b, 13, eps, quad_loss).unwrap();
        assert_eq!(ea.g_scale, eb.g_scale);
        assert_eq!(ea.loss_plus, eb.loss_plus);
        assert_eq!(ea.loss_minus, eb.loss_minus);
        assert_eq!(a.flat(), b.flat());
    }

    #[test]
    fn cached_preperturbed_matches_seeded_preperturbed() {
        let eps = 1e-3f32;
        let mut a = toy_params(&[64, 40]);
        let mut b = a.clone();
        a.perturb_trainable(21, eps);
        let mut cache = crate::model::params::ZCache::default();
        b.perturb_fill_cache(&mut cache, 21, eps);
        assert_eq!(a.flat(), b.flat());
        let ea = estimate_preperturbed(&mut a, 21, eps, quad_loss).unwrap();
        let eb = estimate_cached_preperturbed(&mut b, &cache, 21, eps, quad_loss).unwrap();
        assert_eq!(ea.g_scale, eb.g_scale);
        assert_eq!(a.flat(), b.flat());
    }

    #[test]
    fn cached_preperturbed_rejects_wrong_seed() {
        let eps = 1e-3f32;
        let mut p = toy_params(&[32]);
        let mut cache = crate::model::params::ZCache::default();
        p.perturb_fill_cache(&mut cache, 5, eps);
        let before = p.clone();
        // asking for seed 6 against a seed-5 cache is a recoverable error
        // and must not touch θ
        assert!(estimate_cached_preperturbed(&mut p, &cache, 6, eps, quad_loss).is_err());
        assert_eq!(p.flat(), before.flat());
    }

    #[test]
    fn preperturbed_failing_oracle_restores_params() {
        let eps = 1e-3f32;
        for fail_at in [1usize, 2] {
            let mut p = toy_params(&[48]);
            let orig = p.clone();
            p.perturb_trainable(3, eps);
            let mut calls = 0;
            let r = estimate_preperturbed(&mut p, 3, eps, |_| {
                calls += 1;
                if calls == fail_at {
                    anyhow::bail!("boom")
                }
                Ok(1.0)
            });
            assert!(r.is_err());
            assert!(p.max_abs_diff(&orig) < 1e-6, "fail_at {fail_at}");
        }
    }

    #[test]
    fn staged_preperturbed_matches_monolithic_and_executes_from_stage() {
        use crate::model::params::{TileSpec, ZCache};
        use crate::runtime::{stream_theta, HostThetaStage};
        let eps = 1e-3f32;
        let flatq = |p: &ParamSet| Ok(p.flat().iter().map(|x| x * x).sum::<f32>());
        for cached in [true, false] {
            for tiles in [TileSpec::by_shards(1), TileSpec::whole_arena()] {
                // monolithic reference
                let mut a = toy_params(&[100, 28]);
                let mut ca = ZCache::default();
                a.perturb_fill_cache(&mut ca, 21, eps);
                let ea = if cached {
                    estimate_cached_preperturbed(&mut a, &ca, 21, eps, flatq).unwrap()
                } else {
                    estimate_preperturbed(&mut a, 21, eps, flatq).unwrap()
                };

                // staged path: every loss reads the STAGED bytes, proving
                // the sink holds exactly θ at both probe points
                let mut b = toy_params(&[100, 28]);
                let mut cb = ZCache::default();
                b.perturb_fill_cache(&mut cb, 21, eps);
                let mut sink = HostThetaStage::default();
                stream_theta(&b, tiles, &mut sink).unwrap();
                let cache = if cached { Some(&cb) } else { None };
                let eb = estimate_staged_preperturbed(
                    &mut b, cache, 21, eps, tiles, &mut sink,
                    |s: &mut HostThetaStage| Ok(s.values().iter().map(|x| x * x).sum::<f32>()),
                )
                .unwrap();
                assert_eq!(ea.g_scale, eb.g_scale, "cached {cached}");
                assert_eq!(ea.loss_plus, eb.loss_plus);
                assert_eq!(ea.loss_minus, eb.loss_minus);
                assert_eq!(a.flat(), b.flat()); // both parked at θ − εz
            }
        }
    }

    #[test]
    fn staged_preperturbed_failing_exec_restores_params() {
        use crate::model::params::{TileSpec, ZCache};
        use crate::runtime::{stream_theta, HostThetaStage};
        let eps = 1e-3f32;
        for fail_at in [1usize, 2] {
            let mut p = toy_params(&[48]);
            let orig = p.clone();
            let mut cache = ZCache::default();
            p.perturb_fill_cache(&mut cache, 3, eps);
            let mut sink = HostThetaStage::default();
            stream_theta(&p, TileSpec::by_shards(1), &mut sink).unwrap();
            let mut calls = 0;
            let r = estimate_staged_preperturbed(
                &mut p, Some(&cache), 3, eps, TileSpec::by_shards(1), &mut sink,
                |_s: &mut HostThetaStage| {
                    calls += 1;
                    if calls == fail_at {
                        anyhow::bail!("boom")
                    }
                    Ok(1.0)
                },
            );
            assert!(r.is_err());
            assert!(p.max_abs_diff(&orig) < 1e-6, "fail_at {fail_at}");
        }
    }

    #[test]
    fn staged_preperturbed_rejects_wrong_seed() {
        use crate::model::params::{TileSpec, ZCache};
        use crate::runtime::{stream_theta, HostThetaStage};
        let eps = 1e-3f32;
        let mut p = toy_params(&[32]);
        let mut cache = ZCache::default();
        p.perturb_fill_cache(&mut cache, 5, eps);
        let mut sink = HostThetaStage::default();
        stream_theta(&p, TileSpec::whole_arena(), &mut sink).unwrap();
        let before = p.clone();
        let r = estimate_staged_preperturbed(
            &mut p, Some(&cache), 6, eps, TileSpec::whole_arena(), &mut sink,
            |_s: &mut HostThetaStage| Ok(1.0),
        );
        assert!(r.is_err());
        assert_eq!(p.flat(), before.flat());
    }

    #[test]
    fn different_seeds_give_different_estimates() {
        let mut p = toy_params(&[64]);
        let a = estimate_with(&mut p, 1, 1e-3, quad_loss).unwrap();
        let b = estimate_with(&mut p, 2, 1e-3, quad_loss).unwrap();
        assert_ne!(a.g_scale, b.g_scale);
    }
}
