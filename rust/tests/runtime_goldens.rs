//! Integration: the Rust runtime reproduces python-recorded numerics through
//! the compiled HLO artifacts, and the executable cache behaves.
//!
//! Requires `make artifacts` (skipped gracefully if absent).

use helene::data::batcher::Batch;
use helene::runtime::{ModelRunner, Runtime};
use helene::util::json::Json;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime loads"))
}

/// The deterministic batch used by aot.write_goldens.
fn golden_batch(batch: usize, seq: usize, vocab: usize) -> Batch {
    let mut tokens = Vec::with_capacity(batch * seq);
    for b in 0..batch {
        for s in 0..seq {
            tokens.push(((7 * b + 3 * s) % vocab) as i32);
        }
    }
    let labels = (0..batch).map(|b| (b % 4) as i32).collect();
    Batch { tokens, labels, batch, seq }
}

fn goldens(rt: &Runtime) -> Json {
    let text = std::fs::read_to_string(rt.manifest.dir.join("goldens.json")).unwrap();
    Json::parse(&text).unwrap()
}

#[test]
fn losses_match_python_goldens() {
    let Some(rt) = runtime() else { return };
    let g = goldens(&rt);
    for (model, variant) in [
        ("cls-tiny", "ft"),
        ("cls-tiny", "lora"),
        ("cls-tiny", "prefix"),
        ("cls-small", "ft"),
        ("dec-small", "ft"),
        ("lm-small", "ft"),
    ] {
        let key = format!("{model}.{variant}");
        let Some(rec) = g.get(&key) else { continue };
        let want = rec.req("loss").unwrap().as_f64().unwrap() as f32;
        let runner = ModelRunner::new(&rt, model, variant).unwrap();
        let params = runner.load_init_params().unwrap();
        let d = &runner.spec.dims;
        let batch = golden_batch(d.batch, d.max_seq, d.vocab);
        let got = runner.loss(&params, &batch).unwrap();
        assert!(
            (got - want).abs() < 1e-4 * want.abs().max(1.0),
            "{key}: rust {got} vs python {want}"
        );
    }
}

#[test]
fn logits_match_python_goldens() {
    let Some(rt) = runtime() else { return };
    let g = goldens(&rt);
    let rec = g.get("cls-tiny.ft").unwrap();
    let want: Vec<f32> = rec
        .req("logits_row0")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let params = runner.load_init_params().unwrap();
    let d = &runner.spec.dims;
    let batch = golden_batch(d.batch, d.max_seq, d.vocab);
    let got = runner.logits(&params, &batch).unwrap();
    for (i, w) in want.iter().enumerate() {
        assert!((got[i] - w).abs() < 1e-4, "logit {i}: {} vs {w}", got[i]);
    }
}

#[test]
fn pallas_and_ref_graphs_agree_through_pjrt() {
    // the L1 Pallas attention graph and the oracle graph compute the same
    // loss through the full runtime stack
    let Some(rt) = runtime() else { return };
    for model in ["cls-small", "dec-small"] {
        let mut runner = ModelRunner::new(&rt, model, "ft").unwrap();
        runner.set_ref_graph(false);
        let params = runner.load_init_params().unwrap();
        let d = runner.spec.dims.clone();
        let batch = golden_batch(d.batch, d.max_seq, d.vocab);
        let pallas = runner.loss(&params, &batch).unwrap();
        runner.set_ref_graph(true);
        let oracle = runner.loss(&params, &batch).unwrap();
        assert!(
            (pallas - oracle).abs() < 2e-5 * oracle.abs().max(1.0),
            "{model}: pallas {pallas} vs oracle {oracle}"
        );
    }
}

#[test]
fn executable_cache_no_recompilation_in_loop() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let params = runner.load_init_params().unwrap();
    let d = &runner.spec.dims;
    let batch = golden_batch(d.batch, d.max_seq, d.vocab);
    let _ = runner.loss(&params, &batch).unwrap();
    let after_first = rt.compilations();
    for _ in 0..5 {
        let _ = runner.loss(&params, &batch).unwrap();
    }
    assert_eq!(rt.compilations(), after_first, "loop recompiled an executable");
    assert!(rt.executions() >= 6);
}

#[test]
fn loss_grad_gradient_matches_spsa_projection() {
    // consistency across entrypoints: the SPSA projected gradient should
    // approximate zᵀ(exact grad) from loss_grad
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let mut params = runner.load_init_params().unwrap();
    let d = runner.spec.dims.clone();
    let batch = golden_batch(d.batch, d.max_seq, d.vocab);

    let (_, grads) = runner.loss_grad(&params, &batch).unwrap();
    let seed = 1234u64;
    let est = helene::optim::spsa::estimate_with(&mut params, seed, 1e-3, |p| {
        runner.loss(p, &batch)
    })
    .unwrap();
    // recompute zᵀg exactly
    let mut proj = 0f64;
    params.visit_z(seed, |i, z| {
        for (gv, zv) in grads.array(i).iter().zip(z) {
            proj += (*gv as f64) * (*zv as f64);
        }
    });
    let err = (est.g_scale as f64 - proj).abs();
    assert!(
        err < 0.05 * proj.abs().max(0.5),
        "SPSA {} vs exact projection {}",
        est.g_scale,
        proj
    );
}

#[test]
fn jvp_matches_grad_dot_tangent_through_pjrt() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let params = runner.load_init_params().unwrap();
    let d = runner.spec.dims.clone();
    let batch = golden_batch(d.batch, d.max_seq, d.vocab);
    let mut tangent = params.zeros_like();
    tangent.perturb_trainable(77, 1.0);
    let (loss1, jvp) = runner.loss_jvp(&params, &tangent, &batch).unwrap();
    let (loss2, grads) = runner.loss_grad(&params, &batch).unwrap();
    assert!((loss1 - loss2).abs() < 1e-5);
    let dot = grads.trainable_dot(&tangent) as f32;
    assert!((jvp - dot).abs() < 1e-3 * dot.abs().max(1.0), "jvp {jvp} vs dot {dot}");
}

#[test]
fn eval_predictions_cover_split_once() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let params = runner.load_init_params().unwrap();
    let d = runner.spec.dims.clone();
    let data = helene::tasks::generate("sst2", d.vocab, d.max_seq, 4, 3).unwrap();
    // odd-sized split exercises the wrap-and-truncate path
    let split = &data.dev[..11];
    let (preds, labels) = runner.eval_predictions(&params, split, 2).unwrap();
    assert_eq!(preds.len(), 11);
    assert_eq!(labels.len(), 11);
    for (l, e) in labels.iter().zip(split) {
        assert_eq!(*l, e.label);
    }
}
