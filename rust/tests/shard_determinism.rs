//! Parallelism-determinism properties of the sharded flat-arena `ParamSet`.
//!
//! The z-stream contract (DESIGN.md §Sharding, v2): every draw is a pure
//! function of `(seed, flat-position)` — never of scheduling, shard
//! partitioning, or the train mask — so any operation must be **bitwise
//! identical** across rayon pool sizes, the MeZO perturb/restore identity
//! must hold on multi-shard arenas, the fused restore+update path must be
//! bitwise equal to the unfused restore-then-step sequence, and the
//! cross-step prefetch pipeline (§Perf, `train::ZoProtocol`) must be
//! bitwise equal to the naive 4-sweep reference — parameters *and* losses,
//! through eval boundaries and mid-run mask changes, at any thread count.

use helene::model::params::{ParamSet, ZCache, SHARD_SIZE};
use helene::optim::helene::Helene;
use helene::optim::sophia::ZoSophia;
use helene::optim::zo_adam::ZoAdam;
use helene::optim::zo_sgd::{ZoSgd, ZoSgdMomentum};
use helene::optim::{spsa, Optimizer};
use helene::train::{TrainConfig, ZoProtocol};
use helene::util::prop::{forall, Gen};
use helene::util::rng::mix64;

/// Run `f` inside a dedicated rayon pool of `threads` workers.
fn with_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("pool")
        .install(f)
}

/// A multi-shard synthetic arena with randomized (mis)alignment.
fn gen_multi_shard(g: &mut Gen) -> ParamSet {
    let sizes = [
        g.usize_in(1, SHARD_SIZE),
        g.usize_in(SHARD_SIZE, 2 * SHARD_SIZE),
        g.usize_in(1, 300),
        g.usize_in(SHARD_SIZE / 2, SHARD_SIZE + 2),
    ];
    let mut p = ParamSet::synthetic(&sizes, 0.0);
    // randomized contents
    let vals = g.vec_f32(p.n_params(), -2.0, 2.0);
    p.flat_mut().copy_from_slice(&vals);
    p
}

#[test]
fn prop_perturb_bitwise_identical_across_thread_counts() {
    forall("perturb-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let scale = g.f32_in(1e-5, 1e-1);
        let run = |threads: usize| {
            let mut p = base.clone();
            with_pool(threads, || p.perturb_trainable(seed, scale));
            p
        };
        let single = run(1);
        for threads in [2, 4, 8] {
            if single.flat() != run(threads).flat() {
                return Err(format!("perturb differs at {threads} threads"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizer_steps_bitwise_identical_across_thread_counts() {
    forall("step-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let g_scale = g.f32_in(-2.0, 2.0);
        let which = g.usize_in(0, 4);
        let run = |threads: usize| -> Result<ParamSet, String> {
            let mut p = base.clone();
            let mut opt: Box<dyn Optimizer + Send> = match which {
                0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
                1 => Box::new(ZoAdam::new(1e-3, true)),
                2 => Box::new(ZoSophia::new(1e-3)),
                _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)),
            };
            opt.init(&p);
            with_pool(threads, || opt.step_zo(&mut p, g_scale, seed))
                .map_err(|e| e.to_string())?;
            Ok(p)
        };
        let single = run(1)?;
        let eight = run(8)?;
        if single.flat() != eight.flat() {
            return Err(format!("optimizer {which} differs between 1 and 8 threads"));
        }
        Ok(())
    });
}

#[test]
fn prop_perturb_restore_drift_bounded_on_sharded_arena() {
    // the SPSA cycle +ε / −2ε / +ε re-adds identical values per element, so
    // drift stays within the ulp bound the old sequential store guaranteed
    forall("sharded-restore-drift", |g| {
        let mut p = gen_multi_shard(g);
        let orig = p.clone();
        let seed = g.u64();
        let eps = g.f32_in(1e-6, 1e-1);
        p.perturb_trainable(seed, eps);
        p.perturb_trainable(seed, -2.0 * eps);
        p.perturb_trainable(seed, eps);
        let drift = p.max_abs_diff(&orig);
        let bound = 8.0 * f32::EPSILON * (2.0 + 6.0 * eps);
        if drift > bound {
            return Err(format!("drift {drift} > bound {bound} (eps {eps})"));
        }
        Ok(())
    });
}

#[test]
fn prop_zcache_path_bitwise_matches_regeneration() {
    forall("zcache-vs-regen", |g| {
        let mut a = gen_multi_shard(g);
        let mut b = a.clone();
        let seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let quad = |q: &ParamSet| Ok(q.flat().iter().map(|x| x * x).sum::<f32>());
        let mut cache = ZCache::default();
        let ea = spsa::estimate_with(&mut a, seed, eps, quad).map_err(|e| e.to_string())?;
        let eb = spsa::estimate_cached(&mut b, &mut cache, seed, eps, quad)
            .map_err(|e| e.to_string())?;
        if ea.g_scale != eb.g_scale || a.flat() != b.flat() {
            return Err("cached SPSA cycle diverged from regeneration".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fused_step_bitwise_matches_unfused() {
    // θ after (unrestored probes + step_zo_fused) must equal θ after
    // (restored probes + step_zo) bit-for-bit: the fusion only merges
    // sweeps, never changes per-element arithmetic. Covers the four
    // specialized optimizers and one default-impl optimizer, with the
    // z-cache both on and off.
    forall("fused-vs-unfused", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let which = g.usize_in(0, 5);
        let cached = g.bool();
        let mk = |w: usize| -> Box<dyn Optimizer> {
            match w {
                0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
                1 => Box::new(ZoAdam::new(1e-3, true)),
                2 => Box::new(ZoSgd::new(1e-3)),
                3 => Box::new(ZoSophia::new(1e-3)),
                _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)), // default-impl path
            }
        };
        let quad = |q: &ParamSet| Ok(q.flat().iter().map(|x| x * x).sum::<f32>());

        // unfused: restored probe pair, then the plain step
        let mut p1 = base.clone();
        let mut o1 = mk(which);
        o1.init(&p1);
        let mut c1 = ZCache::default();
        let e1 = if cached {
            spsa::estimate_cached(&mut p1, &mut c1, seed, eps, quad)
        } else {
            spsa::estimate_with(&mut p1, seed, eps, quad)
        }
        .map_err(|e| e.to_string())?;
        if cached {
            o1.step_zo_cached(&mut p1, e1.g_scale, e1.seed, &c1)
        } else {
            o1.step_zo(&mut p1, e1.g_scale, e1.seed)
        }
        .map_err(|e| e.to_string())?;

        // fused: unrestored probe pair, restore folded into the step
        let mut p2 = base.clone();
        let mut o2 = mk(which);
        o2.init(&p2);
        let mut c2 = ZCache::default();
        let e2 = if cached {
            spsa::estimate_cached_unrestored(&mut p2, &mut c2, seed, eps, quad)
        } else {
            spsa::estimate_unrestored(&mut p2, seed, eps, quad)
        }
        .map_err(|e| e.to_string())?;
        let cache_ref = if cached { Some(&c2) } else { None };
        o2.step_zo_fused(&mut p2, e2.g_scale, e2.seed, eps, cache_ref)
            .map_err(|e| e.to_string())?;

        if e1.g_scale != e2.g_scale {
            return Err("probe estimates diverged".into());
        }
        if p1.flat() != p2.flat() {
            return Err(format!(
                "fused != unfused for optimizer {which} (cached={cached})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fused_cycle_bitwise_identical_across_thread_counts() {
    // the fused restore+update sweep keeps the thread-count invariance of
    // the separate sweeps, across 1/2/4/8-worker pools
    forall("fused-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let run = |threads: usize| -> Result<ParamSet, String> {
            let mut p = base.clone();
            let mut opt = Helene::paper_defaults().with_lr(1e-3);
            opt.init(&p);
            let mut cache = ZCache::default();
            with_pool(threads, || -> anyhow::Result<()> {
                let est = spsa::estimate_cached_unrestored(
                    &mut p, &mut cache, seed, eps,
                    |q| Ok(q.flat().iter().map(|x| x * x).sum::<f32>()),
                )?;
                opt.step_zo_fused(&mut p, est.g_scale, est.seed, eps, Some(&cache))
            })
            .map_err(|e| e.to_string())?;
            Ok(p)
        };
        let single = run(1)?;
        for threads in [2, 4, 8] {
            if single.flat() != run(threads)?.flat() {
                return Err(format!("fused cycle differs at {threads} threads"));
            }
        }
        Ok(())
    });
}

#[test]
fn freezing_one_shard_leaves_other_shards_draws_unchanged() {
    // arrays aligned to whole shards: freezing array 0 must not change the
    // z applied to array 1 (position-pure draws)
    let mut all = ParamSet::synthetic(&[SHARD_SIZE, SHARD_SIZE], 1.0);
    let mut partial = all.clone();
    partial.train_mask[0] = false;
    all.perturb_trainable(5, 0.1);
    partial.perturb_trainable(5, 0.1);
    assert_eq!(all.array(1), partial.array(1), "shard 1 draws shifted");
    assert!(partial.array(0).iter().all(|&x| x == 1.0), "frozen shard moved");
}

// ---------------------------------------------------------------------------
// Cross-step prefetch pipeline (§Perf): two sweeps per steady-state step,
// bitwise identical to the naive 4-sweep reference.

/// The quadratic oracle the pipeline properties probe (minimum away from
/// the arena values so gradients are non-trivial).
fn pipe_loss(q: &ParamSet) -> anyhow::Result<f32> {
    Ok(q.flat().iter().map(|x| (x - 0.3) * (x - 0.3)).sum::<f32>())
}

fn pipe_opt(which: usize) -> Box<dyn Optimizer> {
    match which {
        0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
        1 => Box::new(ZoAdam::new(1e-3, true)),
        2 => Box::new(ZoSgd::new(1e-3)),
        3 => Box::new(ZoSophia::new(1e-3)),
        _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)), // default-impl path
    }
}

const PIPE_STEPS: u64 = 6;
const PIPE_EVAL_AT: u64 = 3; // eval break + train_only_layers narrowing here
const PIPE_MASK: &[&str] = &["layer0", "layer2", "layer3"];

/// The naive 4-sweep reference: perturb +εz → L⁺ → −2εz → L⁻ → restore →
/// plain seeded step; the eval reads pristine θ after the step, and the
/// mask narrows right after the eval. Returns final θ plus every recorded
/// loss (per-step SPSA losses and the eval loss).
fn run_naive_reference(
    base: &ParamSet,
    which: usize,
    run_seed: u64,
    eps: f32,
) -> Result<(ParamSet, Vec<f32>), String> {
    let mut p = base.clone();
    let mut opt = pipe_opt(which);
    opt.init(&p);
    let mut losses = Vec::new();
    for step in 1..=PIPE_STEPS {
        let seed = mix64(run_seed, step);
        let est = spsa::estimate_with(&mut p, seed, eps, pipe_loss).map_err(|e| e.to_string())?;
        opt.step_zo(&mut p, est.g_scale, est.seed).map_err(|e| e.to_string())?;
        losses.push(est.loss());
        if step == PIPE_EVAL_AT {
            losses.push(pipe_loss(&p).unwrap()); // eval on pristine θ
            p.restrict_to_layers(PIPE_MASK).map_err(|e| e.to_string())?;
        }
    }
    Ok((p, losses))
}

/// The cross-step pipeline through `train::ZoProtocol`: the eval step and
/// the final step are boundaries; everything between runs the two-sweep
/// steady state (asserted via the instrumented sweep counter for the
/// single-sweep optimizers).
fn run_prefetch_pipeline(
    base: &ParamSet,
    which: usize,
    run_seed: u64,
    eps: f32,
    cache_z: bool,
) -> Result<(ParamSet, Vec<f32>), String> {
    let cfg = TrainConfig {
        spsa_eps: eps,
        seed: run_seed,
        cache_z,
        fuse_restore: true,
        prefetch_perturb: true,
        ..Default::default()
    };
    let mut proto = ZoProtocol::new(&cfg);
    let mut p = base.clone();
    let mut opt = pipe_opt(which);
    opt.init(&p);
    let mut losses = Vec::new();
    for step in 1..=PIPE_STEPS {
        let boundary = step == PIPE_EVAL_AT || step == PIPE_STEPS;
        let entered_pristine = proto.pending().is_none();
        let before = p.sweep_count();
        let est = proto
            .step(
                opt.as_mut(),
                &mut p,
                mix64(run_seed, step),
                mix64(run_seed, step + 1),
                boundary,
                pipe_loss,
            )
            .map_err(|e| e.to_string())?;
        losses.push(est.loss());
        if which < 4 {
            // single-sweep optimizers: 2 sweeps/step, +1 prologue sweep
            // when the previous step was a boundary
            let expect = if entered_pristine { 3 } else { 2 };
            let got = p.sweep_count() - before;
            if got != expect {
                return Err(format!("step {step}: {got} sweeps, expected {expect}"));
            }
        }
        if step == PIPE_EVAL_AT {
            if proto.pending().is_some() {
                return Err("eval boundary left a pending perturbation".into());
            }
            losses.push(pipe_loss(&p).unwrap());
            p.restrict_to_layers(PIPE_MASK).map_err(|e| e.to_string())?;
        }
    }
    proto.finish(&mut p);
    Ok((p, losses))
}

#[test]
fn prop_prefetch_pipeline_bitwise_matches_naive_reference() {
    // N steps of the full cross-step pipeline — prologue, steady state,
    // an eval break with a train_only_layers narrowing, epilogue — must
    // reproduce the naive 4-sweep protocol bit-for-bit: final parameters
    // AND every loss, for every covered optimizer, z-cache on and off.
    // (24 explicit cases: each runs 12 full multi-shard training steps.)
    helene::util::prop::forall_seeded("prefetch-pipeline-vs-naive", 0x5EED_CAFE, 24, |g| {
        let base = gen_multi_shard(g);
        let run_seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let which = g.usize_in(0, 5);
        let cache_z = g.bool();
        let (p_ref, l_ref) = run_naive_reference(&base, which, run_seed, eps)?;
        let (p_pipe, l_pipe) = run_prefetch_pipeline(&base, which, run_seed, eps, cache_z)?;
        if l_ref != l_pipe {
            return Err(format!(
                "losses diverged for optimizer {which} (cache_z {cache_z}): {l_ref:?} vs {l_pipe:?}"
            ));
        }
        if p_ref.flat() != p_pipe.flat() {
            return Err(format!(
                "final params diverged for optimizer {which} (cache_z {cache_z})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_prefetch_pipeline_bitwise_identical_across_thread_counts() {
    // the dual-stream sweep keeps the thread-count invariance: the whole
    // N-step pipeline (eval break and mask change included) is bitwise
    // identical across 1/2/4/8-worker pools (12 explicit cases: each runs
    // the 6-step pipeline under four different pools)
    helene::util::prop::forall_seeded("prefetch-pipeline-thread-invariance", 0x7EED_5EED, 12, |g| {
        let base = gen_multi_shard(g);
        let run_seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let which = g.usize_in(0, 5); // include the default-impl optimizer
        let cache_z = g.bool();
        let run = |threads: usize| -> Result<(ParamSet, Vec<f32>), String> {
            with_pool(threads, || run_prefetch_pipeline(&base, which, run_seed, eps, cache_z))
        };
        let (p1, l1) = run(1)?;
        for threads in [2, 4, 8] {
            let (pt, lt) = run(threads)?;
            if p1.flat() != pt.flat() || l1 != lt {
                return Err(format!(
                    "pipeline differs at {threads} threads (optimizer {which}, cache_z {cache_z})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn helene_full_cycle_identical_between_pools() {
    // several SPSA + step cycles end-to-end under different pools
    let run = |threads: usize| {
        with_pool(threads, || {
            let mut p = ParamSet::synthetic(&[SHARD_SIZE + 7, 3 * SHARD_SIZE / 2], 0.5);
            let mut opt = Helene::paper_defaults().with_lr(3e-3);
            opt.init(&p);
            let mut cache = ZCache::default();
            for s in 0..4 {
                let est = spsa::estimate_cached(&mut p, &mut cache, 100 + s, 1e-3, |q| {
                    Ok(q.flat().iter().map(|x| x * x).sum::<f32>())
                })
                .unwrap();
                opt.step_zo_cached(&mut p, est.g_scale, est.seed, &cache).unwrap();
            }
            p
        })
    };
    let a = run(1);
    let b = run(4);
    let c = run(8);
    assert_eq!(a.flat(), b.flat());
    assert_eq!(b.flat(), c.flat());
}
