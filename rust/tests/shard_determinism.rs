//! Parallelism-determinism properties of the sharded flat-arena `ParamSet`.
//!
//! The z-stream contract (DESIGN.md §Sharding, v2): every draw is a pure
//! function of `(seed, flat-position)` — never of scheduling, shard
//! partitioning, or the train mask — so any operation must be **bitwise
//! identical** across rayon pool sizes, the MeZO perturb/restore identity
//! must hold on multi-shard arenas, the fused restore+update path must be
//! bitwise equal to the unfused restore-then-step sequence, and the
//! cross-step prefetch pipeline (§Perf, `train::ZoProtocol`) must be
//! bitwise equal to the naive 4-sweep reference — parameters *and* losses,
//! through eval boundaries and mid-run mask changes, at any thread count.

use helene::model::checkpoint;
use helene::model::params::{Codec, ParamSet, TileSpec, ZCache, SHARD_SIZE};
use helene::runtime::HostThetaStage;
use helene::optim::helene::Helene;
use helene::optim::sophia::ZoSophia;
use helene::optim::zo_adam::ZoAdam;
use helene::optim::zo_sgd::{ZoSgd, ZoSgdMomentum};
use helene::optim::{spsa, Optimizer};
use helene::train::{TrainConfig, ZoProtocol};
use helene::util::prop::{forall, Gen};
use helene::util::rng::mix64;

/// Run `f` inside a dedicated rayon pool of `threads` workers.
fn with_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("pool")
        .install(f)
}

/// A multi-shard synthetic arena with randomized (mis)alignment.
fn gen_multi_shard(g: &mut Gen) -> ParamSet {
    let sizes = [
        g.usize_in(1, SHARD_SIZE),
        g.usize_in(SHARD_SIZE, 2 * SHARD_SIZE),
        g.usize_in(1, 300),
        g.usize_in(SHARD_SIZE / 2, SHARD_SIZE + 2),
    ];
    let mut p = ParamSet::synthetic(&sizes, 0.0);
    // randomized contents
    let vals = g.vec_f32(p.n_params(), -2.0, 2.0);
    p.flat_mut().copy_from_slice(&vals);
    p
}

#[test]
fn prop_perturb_bitwise_identical_across_thread_counts() {
    forall("perturb-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let scale = g.f32_in(1e-5, 1e-1);
        let run = |threads: usize| {
            let mut p = base.clone();
            with_pool(threads, || p.perturb_trainable(seed, scale));
            p
        };
        let single = run(1);
        for threads in [2, 4, 8] {
            if single.flat() != run(threads).flat() {
                return Err(format!("perturb differs at {threads} threads"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_k_seed_perturb_matches_sequential_and_is_thread_invariant() {
    forall("k-seed-perturb", |g| {
        let base = gen_multi_shard(g);
        let k = [1usize, 2, 4, 8][g.usize_in(0, 4)];
        let step_seed = g.u64();
        let probes: Vec<(u64, f32)> = (0..k)
            .map(|i| (spsa::probe_seed(step_seed, i), g.f32_in(-1e-2, 1e-2)))
            .collect();
        for codec in [Codec::F32, Codec::Bf16] {
            let arena = base.clone().with_codec(codec);
            // single-seed reference: one sweep per probe seed
            let mut seq = arena.clone();
            for &(s, sc) in &probes {
                seq.perturb_trainable(s, sc);
            }
            let run = |threads: usize| {
                let mut p = arena.clone();
                with_pool(threads, || p.perturb_trainable_k(&probes));
                p
            };
            let single = run(1);
            // the k-seed fused sweep is bitwise invariant across pool sizes
            // in BOTH codecs (per-element rounding, shard-local staging)
            for threads in [2, 4, 8] {
                if !single.bits_eq(&run(threads)) {
                    return Err(format!(
                        "k={k} perturb differs at {threads} threads ({codec:?})"
                    ));
                }
            }
            match codec {
                // f32: the fused k-stream accumulation is the same f32 op
                // sequence as k single sweeps — bitwise equal
                Codec::F32 => {
                    if single.flat() != seq.flat() {
                        return Err(format!("k={k} fused != sequential (f32)"));
                    }
                }
                // bf16: one rounded store vs k — bounded by the §Precision
                // per-store cost, (k+1)·M/256 with M from the fixture range
                Codec::Bf16 => {
                    let bound = (k as f32 + 1.0) * 2.5 / 256.0;
                    let worst = single
                        .flat_f32()
                        .iter()
                        .zip(seq.flat_f32().iter())
                        .map(|(a, b)| (a - b).abs())
                        .fold(0f32, f32::max);
                    if worst > bound {
                        return Err(format!(
                            "k={k} bf16 fused drifted {worst} > {bound} from sequential"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizer_steps_bitwise_identical_across_thread_counts() {
    forall("step-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let g_scale = g.f32_in(-2.0, 2.0);
        let which = g.usize_in(0, 4);
        let run = |threads: usize| -> Result<ParamSet, String> {
            let mut p = base.clone();
            let mut opt: Box<dyn Optimizer + Send> = match which {
                0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
                1 => Box::new(ZoAdam::new(1e-3, true)),
                2 => Box::new(ZoSophia::new(1e-3)),
                _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)),
            };
            opt.init(&p);
            with_pool(threads, || opt.step_zo(&mut p, g_scale, seed))
                .map_err(|e| e.to_string())?;
            Ok(p)
        };
        let single = run(1)?;
        let eight = run(8)?;
        if single.flat() != eight.flat() {
            return Err(format!("optimizer {which} differs between 1 and 8 threads"));
        }
        Ok(())
    });
}

#[test]
fn prop_perturb_restore_drift_bounded_on_sharded_arena() {
    // the SPSA cycle +ε / −2ε / +ε re-adds identical values per element, so
    // drift stays within the ulp bound the old sequential store guaranteed
    forall("sharded-restore-drift", |g| {
        let mut p = gen_multi_shard(g);
        let orig = p.clone();
        let seed = g.u64();
        let eps = g.f32_in(1e-6, 1e-1);
        p.perturb_trainable(seed, eps);
        p.perturb_trainable(seed, -2.0 * eps);
        p.perturb_trainable(seed, eps);
        let drift = p.max_abs_diff(&orig);
        let bound = 8.0 * f32::EPSILON * (2.0 + 6.0 * eps);
        if drift > bound {
            return Err(format!("drift {drift} > bound {bound} (eps {eps})"));
        }
        Ok(())
    });
}

#[test]
fn prop_zcache_path_bitwise_matches_regeneration() {
    forall("zcache-vs-regen", |g| {
        let mut a = gen_multi_shard(g);
        let mut b = a.clone();
        let seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let quad = |q: &ParamSet| Ok(q.flat().iter().map(|x| x * x).sum::<f32>());
        let mut cache = ZCache::default();
        let ea = spsa::estimate_with(&mut a, seed, eps, quad).map_err(|e| e.to_string())?;
        let eb = spsa::estimate_cached(&mut b, &mut cache, seed, eps, quad)
            .map_err(|e| e.to_string())?;
        if ea.g_scale != eb.g_scale || a.flat() != b.flat() {
            return Err("cached SPSA cycle diverged from regeneration".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fused_step_bitwise_matches_unfused() {
    // θ after (unrestored probes + step_zo_fused) must equal θ after
    // (restored probes + step_zo) bit-for-bit: the fusion only merges
    // sweeps, never changes per-element arithmetic. Covers the four
    // specialized optimizers and one default-impl optimizer, with the
    // z-cache both on and off.
    forall("fused-vs-unfused", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let which = g.usize_in(0, 5);
        let cached = g.bool();
        let mk = |w: usize| -> Box<dyn Optimizer> {
            match w {
                0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
                1 => Box::new(ZoAdam::new(1e-3, true)),
                2 => Box::new(ZoSgd::new(1e-3)),
                3 => Box::new(ZoSophia::new(1e-3)),
                _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)), // default-impl path
            }
        };
        let quad = |q: &ParamSet| Ok(q.flat().iter().map(|x| x * x).sum::<f32>());

        // unfused: restored probe pair, then the plain step
        let mut p1 = base.clone();
        let mut o1 = mk(which);
        o1.init(&p1);
        let mut c1 = ZCache::default();
        let e1 = if cached {
            spsa::estimate_cached(&mut p1, &mut c1, seed, eps, quad)
        } else {
            spsa::estimate_with(&mut p1, seed, eps, quad)
        }
        .map_err(|e| e.to_string())?;
        if cached {
            o1.step_zo_cached(&mut p1, e1.g_scale, e1.seed, &c1)
        } else {
            o1.step_zo(&mut p1, e1.g_scale, e1.seed)
        }
        .map_err(|e| e.to_string())?;

        // fused: unrestored probe pair, restore folded into the step
        let mut p2 = base.clone();
        let mut o2 = mk(which);
        o2.init(&p2);
        let mut c2 = ZCache::default();
        let e2 = if cached {
            spsa::estimate_cached_unrestored(&mut p2, &mut c2, seed, eps, quad)
        } else {
            spsa::estimate_unrestored(&mut p2, seed, eps, quad)
        }
        .map_err(|e| e.to_string())?;
        let cache_ref = if cached { Some(&c2) } else { None };
        o2.step_zo_fused(&mut p2, e2.g_scale, e2.seed, eps, cache_ref)
            .map_err(|e| e.to_string())?;

        if e1.g_scale != e2.g_scale {
            return Err("probe estimates diverged".into());
        }
        if p1.flat() != p2.flat() {
            return Err(format!(
                "fused != unfused for optimizer {which} (cached={cached})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fused_cycle_bitwise_identical_across_thread_counts() {
    // the fused restore+update sweep keeps the thread-count invariance of
    // the separate sweeps, across 1/2/4/8-worker pools
    forall("fused-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let run = |threads: usize| -> Result<ParamSet, String> {
            let mut p = base.clone();
            let mut opt = Helene::paper_defaults().with_lr(1e-3);
            opt.init(&p);
            let mut cache = ZCache::default();
            with_pool(threads, || -> anyhow::Result<()> {
                let est = spsa::estimate_cached_unrestored(
                    &mut p, &mut cache, seed, eps,
                    |q| Ok(q.flat().iter().map(|x| x * x).sum::<f32>()),
                )?;
                opt.step_zo_fused(&mut p, est.g_scale, est.seed, eps, Some(&cache))
            })
            .map_err(|e| e.to_string())?;
            Ok(p)
        };
        let single = run(1)?;
        for threads in [2, 4, 8] {
            if single.flat() != run(threads)?.flat() {
                return Err(format!("fused cycle differs at {threads} threads"));
            }
        }
        Ok(())
    });
}

#[test]
fn freezing_one_shard_leaves_other_shards_draws_unchanged() {
    // arrays aligned to whole shards: freezing array 0 must not change the
    // z applied to array 1 (position-pure draws)
    let mut all = ParamSet::synthetic(&[SHARD_SIZE, SHARD_SIZE], 1.0);
    let mut partial = all.clone();
    partial.train_mask[0] = false;
    all.perturb_trainable(5, 0.1);
    partial.perturb_trainable(5, 0.1);
    assert_eq!(all.array(1), partial.array(1), "shard 1 draws shifted");
    assert!(partial.array(0).iter().all(|&x| x == 1.0), "frozen shard moved");
}

// ---------------------------------------------------------------------------
// Cross-step prefetch pipeline (§Perf): two sweeps per steady-state step,
// bitwise identical to the naive 4-sweep reference.

/// The quadratic oracle the pipeline properties probe (minimum away from
/// the arena values so gradients are non-trivial). Reads the f32 values,
/// so it serves both codecs: for f32 the borrow is free and the sum is
/// bitwise the historical oracle; for bf16 it sees the widened stored
/// values — exactly what a loss execution would be fed.
fn pipe_loss(q: &ParamSet) -> anyhow::Result<f32> {
    Ok(q.flat_f32().iter().map(|x| (x - 0.3) * (x - 0.3)).sum::<f32>())
}

fn pipe_opt(which: usize) -> Box<dyn Optimizer> {
    match which {
        0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
        1 => Box::new(ZoAdam::new(1e-3, true)),
        2 => Box::new(ZoSgd::new(1e-3)),
        3 => Box::new(ZoSophia::new(1e-3)),
        _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)), // default-impl path
    }
}

const PIPE_STEPS: u64 = 6;
const PIPE_EVAL_AT: u64 = 3; // eval break + train_only_layers narrowing here
const PIPE_MASK: &[&str] = &["layer0", "layer2", "layer3"];

/// The naive 4-sweep reference: perturb +εz → L⁺ → −2εz → L⁻ → restore →
/// plain seeded step; the eval reads pristine θ after the step, and the
/// mask narrows right after the eval. Returns final θ plus every recorded
/// loss (per-step SPSA losses and the eval loss).
fn run_naive_reference(
    base: &ParamSet,
    which: usize,
    run_seed: u64,
    eps: f32,
) -> Result<(ParamSet, Vec<f32>), String> {
    let mut p = base.clone();
    let mut opt = pipe_opt(which);
    opt.init(&p);
    let mut losses = Vec::new();
    for step in 1..=PIPE_STEPS {
        let seed = mix64(run_seed, step);
        let est = spsa::estimate_with(&mut p, seed, eps, pipe_loss).map_err(|e| e.to_string())?;
        opt.step_zo(&mut p, est.g_scale, est.seed).map_err(|e| e.to_string())?;
        losses.push(est.loss());
        if step == PIPE_EVAL_AT {
            losses.push(pipe_loss(&p).unwrap()); // eval on pristine θ
            p.restrict_to_layers(PIPE_MASK).map_err(|e| e.to_string())?;
        }
    }
    Ok((p, losses))
}

/// The cross-step pipeline through `train::ZoProtocol`: the eval step and
/// the final step are boundaries; everything between runs the two-sweep
/// steady state (asserted via the instrumented sweep counter for the
/// single-sweep optimizers).
fn run_prefetch_pipeline(
    base: &ParamSet,
    which: usize,
    run_seed: u64,
    eps: f32,
    cache_z: bool,
) -> Result<(ParamSet, Vec<f32>), String> {
    let cfg = TrainConfig {
        spsa_eps: eps,
        seed: run_seed,
        cache_z,
        fuse_restore: true,
        prefetch_perturb: true,
        ..Default::default()
    };
    let mut proto = ZoProtocol::new(&cfg);
    let mut p = base.clone();
    let mut opt = pipe_opt(which);
    opt.init(&p);
    let mut losses = Vec::new();
    for step in 1..=PIPE_STEPS {
        let boundary = step == PIPE_EVAL_AT || step == PIPE_STEPS;
        let entered_pristine = proto.pending().is_none();
        let before = p.sweep_count();
        let est = proto
            .step(
                opt.as_mut(),
                &mut p,
                mix64(run_seed, step),
                mix64(run_seed, step + 1),
                boundary,
                pipe_loss,
            )
            .map_err(|e| e.to_string())?;
        losses.push(est.loss());
        if which < 4 {
            // single-sweep optimizers: 2 sweeps/step, +1 prologue sweep
            // when the previous step was a boundary
            let expect = if entered_pristine { 3 } else { 2 };
            let got = p.sweep_count() - before;
            if got != expect {
                return Err(format!("step {step}: {got} sweeps, expected {expect}"));
            }
        }
        if step == PIPE_EVAL_AT {
            if proto.pending().is_some() {
                return Err("eval boundary left a pending perturbation".into());
            }
            losses.push(pipe_loss(&p).unwrap());
            p.restrict_to_layers(PIPE_MASK).map_err(|e| e.to_string())?;
        }
    }
    proto.finish(&mut p);
    Ok((p, losses))
}

/// The tiled θ-streaming pipeline (`ZoProtocol::step_staged`,
/// `TrainConfig::tiled_sweeps`): identical protocol schedule to
/// [`run_prefetch_pipeline`], but every sweep runs tile-by-tile against a
/// [`HostThetaStage`] staged-upload sink — and, crucially, **every probe
/// loss is computed from the STAGED bytes**, not from `params`, so any
/// divergence between the staged generation and θ shows up as a loss
/// mismatch against the monolithic run.
fn run_staged_pipeline(
    base: &ParamSet,
    which: usize,
    run_seed: u64,
    eps: f32,
    cache_z: bool,
    tiles: TileSpec,
) -> Result<(ParamSet, Vec<f32>), String> {
    let cfg = TrainConfig {
        spsa_eps: eps,
        seed: run_seed,
        cache_z,
        fuse_restore: true,
        prefetch_perturb: true,
        tiled_sweeps: Some(tiles.shards_per_tile()),
        ..Default::default()
    };
    let mut proto = ZoProtocol::new(&cfg);
    let mut p = base.clone();
    let mut opt = pipe_opt(which);
    opt.init(&p);
    let mut sink = HostThetaStage::default();
    let mut losses = Vec::new();
    for step in 1..=PIPE_STEPS {
        let boundary = step == PIPE_EVAL_AT || step == PIPE_STEPS;
        let entered_pristine = proto.pending().is_none();
        let before = p.sweep_count();
        let est = proto
            .step_staged(
                opt.as_mut(),
                &mut p,
                mix64(run_seed, step),
                mix64(run_seed, step + 1),
                boundary,
                tiles,
                &mut sink,
                |s: &mut HostThetaStage| {
                    Ok(s.values().iter().map(|x| (x - 0.3) * (x - 0.3)).sum::<f32>())
                },
            )
            .map_err(|e| e.to_string())?;
        losses.push(est.loss());
        if which < 4 {
            // the tiled odometer must agree with the monolithic pipeline:
            // 2 sweeps/step steady state, +1 prologue after a boundary
            let expect = if entered_pristine { 3 } else { 2 };
            let got = p.sweep_count() - before;
            if got != expect {
                return Err(format!("step {step}: {got} sweeps, expected {expect}"));
            }
        }
        if step == PIPE_EVAL_AT {
            if proto.pending().is_some() {
                return Err("eval boundary left a pending perturbation".into());
            }
            losses.push(pipe_loss(&p).unwrap());
            p.restrict_to_layers(PIPE_MASK).map_err(|e| e.to_string())?;
        }
    }
    proto.finish(&mut p);
    Ok((p, losses))
}

/// The tile sizes the staged properties sweep: one shard, an odd
/// multiple, and the degenerate whole-arena tiling.
fn prop_tiles(g: &mut Gen) -> TileSpec {
    match g.usize_in(0, 3) {
        0 => TileSpec::by_shards(1),
        1 => TileSpec::by_shards(3),
        _ => TileSpec::whole_arena(),
    }
}

#[test]
fn prop_staged_pipeline_bitwise_matches_monolithic_pipeline() {
    // Tiling is pure scheduling: for BOTH codecs the tiled pipeline must
    // reproduce the monolithic prefetch pipeline bit-for-bit — final θ
    // bits AND every loss (the staged losses are computed from the sink,
    // so this also proves every staged generation was exactly θ). Through
    // prefetch-pipeline-vs-naive above, the f32 tiled trajectory is
    // transitively bitwise the naive 4-sweep protocol too. Covers all
    // five optimizers (4 = the default-impl staged path), z-cache on/off,
    // tile sizes {1 shard, odd multiple, whole arena}, eval boundary +
    // mid-run mask narrowing included. (20 explicit cases.)
    helene::util::prop::forall_seeded("staged-pipeline-vs-monolithic", 0x71_1ED5EED, 20, |g| {
        let base = gen_multi_shard(g);
        let base = if g.bool() { base.with_codec(Codec::Bf16) } else { base };
        let run_seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let which = g.usize_in(0, 5);
        let cache_z = g.bool();
        let tiles = prop_tiles(g);
        let (p_mono, l_mono) = run_prefetch_pipeline(&base, which, run_seed, eps, cache_z)?;
        let (p_tile, l_tile) = run_staged_pipeline(&base, which, run_seed, eps, cache_z, tiles)?;
        if l_mono != l_tile {
            return Err(format!(
                "losses diverged for optimizer {which} ({:?}, cache_z {cache_z}, {tiles:?}): \
                 {l_mono:?} vs {l_tile:?}",
                base.codec()
            ));
        }
        if !p_mono.bits_eq(&p_tile) {
            return Err(format!(
                "final params diverged for optimizer {which} ({:?}, cache_z {cache_z}, {tiles:?})",
                base.codec()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_staged_pipeline_bitwise_identical_across_thread_counts() {
    // the per-tile sweeps keep the thread-count invariance: the whole
    // tiled N-step pipeline (staged losses included) is bitwise identical
    // across 1/2/4/8-worker pools (8 explicit cases)
    helene::util::prop::forall_seeded("staged-pipeline-thread-invariance", 0x71_1ED7EAD, 8, |g| {
        let base = gen_multi_shard(g);
        let run_seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let which = g.usize_in(0, 5);
        let cache_z = g.bool();
        let tiles = prop_tiles(g);
        let run = |threads: usize| -> Result<(ParamSet, Vec<f32>), String> {
            with_pool(threads, || run_staged_pipeline(&base, which, run_seed, eps, cache_z, tiles))
        };
        let (p1, l1) = run(1)?;
        for threads in [2, 4, 8] {
            let (pt, lt) = run(threads)?;
            if !p1.bits_eq(&pt) || l1 != lt {
                return Err(format!(
                    "staged pipeline differs at {threads} threads (optimizer {which}, {tiles:?})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn staged_pipeline_with_post_check_optimizer_falls_back_to_classic() {
    // post-check members (ZO-SGD-Cons) are outside the prefetch pipeline;
    // in tiled mode they run the classic protocol against the staged
    // oracle — trajectories must match the plain classic run bitwise
    use helene::optim::zo_sgd::ZoSgdCons;
    let base = {
        let mut g = Gen::new(0xC0_115EED, 0);
        gen_multi_shard(&mut g)
    };
    let cfg = TrainConfig { spsa_eps: 1e-3, seed: 9, ..Default::default() };
    let run = |staged: bool| -> (ParamSet, Vec<f32>) {
        let mut proto = ZoProtocol::new(&cfg);
        let mut p = base.clone();
        let mut opt = ZoSgdCons::new(1e-3);
        opt.init(&p);
        let mut sink = HostThetaStage::default();
        let mut losses = Vec::new();
        for step in 1..=4u64 {
            let est = if staged {
                proto
                    .step_staged(
                        &mut opt,
                        &mut p,
                        mix64(9, step),
                        mix64(9, step + 1),
                        step == 4,
                        TileSpec::by_shards(1),
                        &mut sink,
                        |s: &mut HostThetaStage| {
                            Ok(s.values().iter().map(|x| (x - 0.3) * (x - 0.3)).sum::<f32>())
                        },
                    )
                    .unwrap()
            } else {
                proto
                    .step(
                        &mut opt,
                        &mut p,
                        mix64(9, step),
                        mix64(9, step + 1),
                        step == 4,
                        pipe_loss,
                    )
                    .unwrap()
            };
            losses.push(est.loss());
        }
        (p, losses)
    };
    let (p_classic, l_classic) = run(false);
    let (p_staged, l_staged) = run(true);
    assert_eq!(l_classic, l_staged);
    assert!(p_classic.bits_eq(&p_staged));
}

#[test]
fn prop_prefetch_pipeline_bitwise_matches_naive_reference() {
    // N steps of the full cross-step pipeline — prologue, steady state,
    // an eval break with a train_only_layers narrowing, epilogue — must
    // reproduce the naive 4-sweep protocol bit-for-bit: final parameters
    // AND every loss, for every covered optimizer, z-cache on and off.
    // (24 explicit cases: each runs 12 full multi-shard training steps.)
    helene::util::prop::forall_seeded("prefetch-pipeline-vs-naive", 0x5EED_CAFE, 24, |g| {
        let base = gen_multi_shard(g);
        let run_seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let which = g.usize_in(0, 5);
        let cache_z = g.bool();
        let (p_ref, l_ref) = run_naive_reference(&base, which, run_seed, eps)?;
        let (p_pipe, l_pipe) = run_prefetch_pipeline(&base, which, run_seed, eps, cache_z)?;
        if l_ref != l_pipe {
            return Err(format!(
                "losses diverged for optimizer {which} (cache_z {cache_z}): {l_ref:?} vs {l_pipe:?}"
            ));
        }
        if p_ref.flat() != p_pipe.flat() {
            return Err(format!(
                "final params diverged for optimizer {which} (cache_z {cache_z})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_prefetch_pipeline_bitwise_identical_across_thread_counts() {
    // the dual-stream sweep keeps the thread-count invariance: the whole
    // N-step pipeline (eval break and mask change included) is bitwise
    // identical across 1/2/4/8-worker pools (12 explicit cases: each runs
    // the 6-step pipeline under four different pools)
    helene::util::prop::forall_seeded("prefetch-pipeline-thread-invariance", 0x7EED_5EED, 12, |g| {
        let base = gen_multi_shard(g);
        let run_seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let which = g.usize_in(0, 5); // include the default-impl optimizer
        let cache_z = g.bool();
        let run = |threads: usize| -> Result<(ParamSet, Vec<f32>), String> {
            with_pool(threads, || run_prefetch_pipeline(&base, which, run_seed, eps, cache_z))
        };
        let (p1, l1) = run(1)?;
        for threads in [2, 4, 8] {
            let (pt, lt) = run(threads)?;
            if p1.flat() != pt.flat() || l1 != lt {
                return Err(format!(
                    "pipeline differs at {threads} threads (optimizer {which}, cache_z {cache_z})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn helene_full_cycle_identical_between_pools() {
    // several SPSA + step cycles end-to-end under different pools
    let run = |threads: usize| {
        with_pool(threads, || {
            let mut p = ParamSet::synthetic(&[SHARD_SIZE + 7, 3 * SHARD_SIZE / 2], 0.5);
            let mut opt = Helene::paper_defaults().with_lr(3e-3);
            opt.init(&p);
            let mut cache = ZCache::default();
            for s in 0..4 {
                let est = spsa::estimate_cached(&mut p, &mut cache, 100 + s, 1e-3, |q| {
                    Ok(q.flat().iter().map(|x| x * x).sum::<f32>())
                })
                .unwrap();
                opt.step_zo_cached(&mut p, est.g_scale, est.seed, &cache).unwrap();
            }
            p
        })
    };
    let a = run(1);
    let b = run(4);
    let c = run(8);
    assert_eq!(a.flat(), b.flat());
    assert_eq!(b.flat(), c.flat());
}

// ---------------------------------------------------------------------------
// bf16 θ-arena battery (DESIGN.md §Precision): drift bounds replacing the
// bitwise pipeline-vs-naive invariant, thread invariance *within* the bf16
// mode, and checkpoint round-trip exactness.

/// A small randomized fixture whose start point is bf16-representable, so
/// a bf16 run and its f32 reference begin at the identical θ. Sized so the
/// §Precision closed-loop bound constants stay meaningful (n small, and
/// lr·‖z‖² ≪ 1 keeps the quadratic feedback non-expansive).
fn bf16_fixture(sizes: &[usize], case_seed: u64) -> (ParamSet, ParamSet) {
    let mut g = Gen::new(case_seed, 0);
    let mut p = ParamSet::synthetic(sizes, 0.0);
    let n = p.n_params();
    p.flat_mut().copy_from_slice(&g.vec_f32(n, -1.5, 1.5));
    let p16 = p.with_codec(Codec::Bf16);
    let p32 = p16.clone().with_codec(Codec::F32);
    (p16, p32)
}

/// The §Precision per-run drift bound: `stores` rounded θ-stores at half a
/// bf16 ulp each (≤ M/256 absolute for values bounded by M), plus the
/// K·σ·√N estimator-noise term of the closed-loop derivation (zero in
/// open-loop tests where the gradient sequence is scripted).
fn bf16_drift_bound(stores: f32, m: f32, est_steps: f32, lr: f32, eps: f32, grad_l2: f32) -> f32 {
    let storage = stores * m / 256.0;
    let sigma_g = grad_l2 * (m / 256.0 / 3f32.sqrt()) / (2.0 * eps);
    let estimator = 8.0 * est_steps.sqrt() * lr * sigma_g * 6.0; // z∞ ≤ 6
    storage + estimator
}

#[test]
fn bf16_open_loop_storage_drift_within_analytic_bound() {
    // With the probe losses scripted (identical across codecs), g_scale is
    // identical and the ZO-SGD update is θ-independent, so the bf16-vs-f32
    // divergence is *pure storage rounding*: one prologue store plus two
    // stores per steady-state step, each at most half a bf16 ulp. The
    // deterministic bound D_N ≤ (2N+1)·M/256 from DESIGN.md §Precision
    // must hold with no probabilistic slack.
    const N: u64 = 20;
    let (eps, lr) = (1e-2f32, 1e-3f32);
    let (start16, start32) = bf16_fixture(&[1500, 700, 300], 0xD81F7);

    let run = |base: &ParamSet| -> ParamSet {
        let cfg = TrainConfig { spsa_eps: eps, seed: 77, ..Default::default() };
        let mut proto = ZoProtocol::new(&cfg);
        let mut p = base.clone();
        let mut opt = ZoSgd::new(lr);
        opt.init(&p);
        let mut call = 0u64;
        for step in 1..=N {
            proto
                .step(&mut opt, &mut p, mix64(77, step), mix64(77, step + 1), step == N, |_q| {
                    call += 1;
                    // scripted probe loss: a deterministic value sequence,
                    // ignoring θ — identical in both codecs
                    Ok(((mix64(99, call) >> 40) as f32) * 2f32.powi(-28))
                })
                .unwrap();
        }
        p
    };
    let end16 = run(&start16);
    let end32 = run(&start32);
    assert_eq!(end16.codec(), Codec::Bf16);
    // every value stays well inside the M = 4 magnitude assumption
    assert!(end16.flat_f32().iter().chain(end32.flat().iter()).all(|x| x.abs() < 3.5));
    let drift = end16.max_abs_diff(&end32);
    let bound = bf16_drift_bound(2.0 * N as f32 + 1.0, 4.0, 0.0, lr, eps, 0.0);
    assert!(drift > 0.0, "bf16 run never rounded — codec path not exercised");
    assert!(drift <= bound, "open-loop drift {drift} > analytic bound {bound}");
}

#[test]
fn bf16_closed_loop_drift_and_loss_within_design_bound() {
    // Full feedback loop on the quadratic oracle: the probe points are
    // rounded, so g_scale itself picks up noise ~ ‖∇L‖₂·(M/256)/(2ε√3)
    // per store, amplified by lr·z into θ. DESIGN.md §Precision derives
    // D_N ≤ (2N+1)·M/256 + K·√N·lr·σ_g·z∞ (K = 8) and the induced loss
    // bound |ΔL| ≤ ‖∇L‖₂·√n·D_N + n·D_N² — both asserted here, plus a
    // 10%-relative sanity guard far below the analytic slack.
    const N: u64 = 12;
    let (eps, lr) = (0.05f32, 1e-3f32);
    let (start16, start32) = bf16_fixture(&[96, 40], 0xC105ED);
    let n = start16.n_params() as f32;

    let run = |base: &ParamSet| -> (ParamSet, Vec<f32>) {
        let cfg = TrainConfig { spsa_eps: eps, seed: 31, ..Default::default() };
        let mut proto = ZoProtocol::new(&cfg);
        let mut p = base.clone();
        let mut opt = ZoSgd::new(lr);
        opt.init(&p);
        let mut losses = Vec::new();
        for step in 1..=N {
            let est = proto
                .step(&mut opt, &mut p, mix64(31, step), mix64(31, step + 1), step == N, pipe_loss)
                .unwrap();
            losses.push(est.loss());
        }
        (p, losses)
    };
    let (end16, l16) = run(&start16);
    let (end32, l32) = run(&start32);
    // the M = 4 magnitude assumption of the bound must actually hold
    assert!(end16.flat_f32().iter().chain(end32.flat().iter()).all(|x| x.abs() < 3.5));
    let drift = end16.max_abs_diff(&end32);
    let grad_l2 = 2.0
        * (start32.flat().iter().map(|&x| ((x - 0.3) as f64).powi(2)).sum::<f64>()).sqrt() as f32;
    let bound = bf16_drift_bound(2.0 * N as f32 + 1.0, 4.0, N as f32, lr, eps, grad_l2);
    assert!(drift > 0.0 && drift <= bound, "closed-loop drift {drift} vs bound {bound}");
    let dtheta = n.sqrt() * bound;
    let loss_bound = grad_l2 * dtheta + dtheta * dtheta;
    for (k, (a, b)) in l16.iter().zip(&l32).enumerate() {
        assert!((a - b).abs() <= loss_bound, "step {k}: loss gap {} > {loss_bound}", (a - b).abs());
    }
    let (lf16, lf32) = (*l16.last().unwrap(), *l32.last().unwrap());
    assert!((lf16 - lf32).abs() <= 0.1 * lf32.abs().max(1.0), "final loss gap {lf16} vs {lf32}");
}

#[test]
fn bf16_pipeline_vs_naive_within_drift_bound_through_eval_and_mask_change() {
    // In bf16 mode the pipeline and the naive 4-sweep protocol round at
    // different points (2 vs 4 stores/step), so PR 3's bitwise invariant
    // becomes the §Precision bound: ≤ (6N+4) stores' storage drift plus two
    // runs' estimator noise. The run includes the eval boundary and the
    // mid-run train_only_layers narrowing, and run_prefetch_pipeline's
    // internal assertions keep pinning sweeps/step == 2 and pristine-θ
    // boundaries for the bf16 codec.
    let eps = 0.05f32;
    let (base16, base32) = bf16_fixture(&[96, 40, 30, 50], 0xBEEF5);
    let run_seed = 0xAB1E5EED;
    let (p_naive, l_naive) = run_naive_reference(&base16, 2, run_seed, eps).unwrap();
    let (p_pipe, l_pipe) = run_prefetch_pipeline(&base16, 2, run_seed, eps, true).unwrap();
    assert_eq!(p_naive.codec(), Codec::Bf16);
    assert_eq!(p_pipe.codec(), Codec::Bf16);
    assert!(p_pipe.flat_f32().iter().chain(p_naive.flat_f32().iter()).all(|x| x.abs() < 3.5));
    let n = base16.n_params() as f32;
    let grad_l2 = 2.0
        * (base32.flat().iter().map(|&x| ((x - 0.3) as f64).powi(2)).sum::<f64>()).sqrt() as f32;
    let steps = PIPE_STEPS as f32;
    // both runs inject estimator noise → twice the single-run K·σ term
    let bound = 2.0 * bf16_drift_bound(3.0 * steps + 2.0, 4.0, steps, 1e-3, eps, grad_l2);
    let drift = p_pipe.max_abs_diff(&p_naive);
    assert!(drift > 0.0, "bf16 pipeline bitwise-matched naive — rounding not exercised?");
    assert!(drift <= bound, "pipeline-vs-naive drift {drift} > bound {bound}");
    let dtheta = n.sqrt() * bound;
    let loss_bound = grad_l2 * dtheta + dtheta * dtheta;
    assert_eq!(l_naive.len(), l_pipe.len());
    for (k, (a, b)) in l_pipe.iter().zip(&l_naive).enumerate() {
        assert!((a - b).abs() <= loss_bound, "loss {k} gap {} > {loss_bound}", (a - b).abs());
    }
    // the f32 codec keeps the PR 3 bitwise invariant — regression guard
    // against the codec refactor loosening the full-precision protocol
    let (q_naive, m_naive) = run_naive_reference(&base32, 2, run_seed, eps).unwrap();
    let (q_pipe, m_pipe) = run_prefetch_pipeline(&base32, 2, run_seed, eps, true).unwrap();
    assert!(q_naive.bits_eq(&q_pipe), "f32 pipeline-vs-naive no longer bitwise");
    assert_eq!(m_naive, m_pipe);
}

#[test]
fn prop_bf16_pipeline_bitwise_identical_across_thread_counts() {
    // Rounding is per-element and staging is shard-local, so the stored
    // bf16 bits — parameters AND losses — must be bitwise identical across
    // 1/2/4/8-worker pools, exactly like the f32 mode. (8 explicit cases
    // through the full 6-step pipeline, eval break + mask change included.)
    helene::util::prop::forall_seeded("bf16-pipeline-thread-invariance", 0xB16_5EED, 8, |g| {
        let base = gen_multi_shard(g).with_codec(Codec::Bf16);
        let run_seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let which = g.usize_in(0, 5);
        let cache_z = g.bool();
        let run = |threads: usize| -> Result<(ParamSet, Vec<f32>), String> {
            with_pool(threads, || run_prefetch_pipeline(&base, which, run_seed, eps, cache_z))
        };
        let (p1, l1) = run(1)?;
        for threads in [2, 4, 8] {
            let (pt, lt) = run(threads)?;
            if !p1.bits_eq(&pt) || l1 != lt {
                return Err(format!(
                    "bf16 pipeline differs at {threads} threads (optimizer {which}, cache_z {cache_z})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn checkpoint_round_trip_continues_training_bitwise() {
    // Store-once semantics make the checkpoint exact in both codecs: at a
    // boundary the arena bits ARE θ, the payload IS the arena bits, so
    // save → load → continue must equal continuing without the round trip
    // bit-for-bit (ZO-SGD is stateless, so θ + the step seeds are the
    // whole training state).
    for codec in [Codec::F32, Codec::Bf16] {
        let (base16, base32) = bf16_fixture(&[600, 300], 0xC4EC_4);
        let base = if codec == Codec::Bf16 { base16 } else { base32 };
        let cfg = TrainConfig { spsa_eps: 1e-2, seed: 5, ..Default::default() };
        let quad = pipe_loss;
        let mut proto = ZoProtocol::new(&cfg);
        let mut p = base.clone();
        let mut opt = ZoSgd::new(1e-3);
        opt.init(&p);
        for step in 1..=3u64 {
            proto
                .step(&mut opt, &mut p, mix64(5, step), mix64(5, step + 1), step == 3, quad)
                .unwrap();
        }
        assert!(proto.pending().is_none(), "save point must be a boundary");
        let dir = std::env::temp_dir().join("helene_ckpt_continue");
        let path = dir.join(format!("ckpt_{}.bin", codec.name()));
        checkpoint::save(&path, 3, &p, &[]).unwrap();

        // branch B first: load from disk, fresh protocol + optimizer
        let (step_loaded, mut pb, extras) = checkpoint::load(&path, p.spec.clone()).unwrap();
        assert_eq!(step_loaded, 3);
        assert!(extras.is_empty());
        assert_eq!(pb.codec(), codec);
        assert!(pb.bits_eq(&p), "{codec:?}: loaded θ differs from saved θ");
        let mut proto_b = ZoProtocol::new(&cfg);
        let mut opt_b = ZoSgd::new(1e-3);
        opt_b.init(&pb);
        for step in 4..=6u64 {
            proto_b
                .step(&mut opt_b, &mut pb, mix64(5, step), mix64(5, step + 1), step == 6, quad)
                .unwrap();
        }

        // branch A: continue in-process with the original protocol state
        for step in 4..=6u64 {
            proto
                .step(&mut opt, &mut p, mix64(5, step), mix64(5, step + 1), step == 6, quad)
                .unwrap();
        }
        assert!(p.bits_eq(&pb), "{codec:?}: checkpoint round trip diverged from direct run");
    }
}
