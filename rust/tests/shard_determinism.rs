//! Parallelism-determinism properties of the sharded flat-arena `ParamSet`.
//!
//! The z-stream contract (DESIGN.md §Sharding, v2): every draw is a pure
//! function of `(seed, flat-position)` — never of scheduling, shard
//! partitioning, or the train mask — so any operation must be **bitwise
//! identical** across rayon pool sizes, the MeZO perturb/restore identity
//! must hold on multi-shard arenas, and the fused restore+update path must
//! be bitwise equal to the unfused restore-then-step sequence.

use helene::model::params::{ParamSet, ZCache, SHARD_SIZE};
use helene::optim::helene::Helene;
use helene::optim::sophia::ZoSophia;
use helene::optim::zo_adam::ZoAdam;
use helene::optim::zo_sgd::{ZoSgd, ZoSgdMomentum};
use helene::optim::{spsa, Optimizer};
use helene::util::prop::{forall, Gen};

/// Run `f` inside a dedicated rayon pool of `threads` workers.
fn with_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("pool")
        .install(f)
}

/// A multi-shard synthetic arena with randomized (mis)alignment.
fn gen_multi_shard(g: &mut Gen) -> ParamSet {
    let sizes = [
        g.usize_in(1, SHARD_SIZE),
        g.usize_in(SHARD_SIZE, 2 * SHARD_SIZE),
        g.usize_in(1, 300),
        g.usize_in(SHARD_SIZE / 2, SHARD_SIZE + 2),
    ];
    let mut p = ParamSet::synthetic(&sizes, 0.0);
    // randomized contents
    let vals = g.vec_f32(p.n_params(), -2.0, 2.0);
    p.flat_mut().copy_from_slice(&vals);
    p
}

#[test]
fn prop_perturb_bitwise_identical_across_thread_counts() {
    forall("perturb-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let scale = g.f32_in(1e-5, 1e-1);
        let run = |threads: usize| {
            let mut p = base.clone();
            with_pool(threads, || p.perturb_trainable(seed, scale));
            p
        };
        let single = run(1);
        for threads in [2, 4, 8] {
            if single.flat() != run(threads).flat() {
                return Err(format!("perturb differs at {threads} threads"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizer_steps_bitwise_identical_across_thread_counts() {
    forall("step-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let g_scale = g.f32_in(-2.0, 2.0);
        let which = g.usize_in(0, 4);
        let run = |threads: usize| -> Result<ParamSet, String> {
            let mut p = base.clone();
            let mut opt: Box<dyn Optimizer + Send> = match which {
                0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
                1 => Box::new(ZoAdam::new(1e-3, true)),
                2 => Box::new(ZoSophia::new(1e-3)),
                _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)),
            };
            opt.init(&p);
            with_pool(threads, || opt.step_zo(&mut p, g_scale, seed))
                .map_err(|e| e.to_string())?;
            Ok(p)
        };
        let single = run(1)?;
        let eight = run(8)?;
        if single.flat() != eight.flat() {
            return Err(format!("optimizer {which} differs between 1 and 8 threads"));
        }
        Ok(())
    });
}

#[test]
fn prop_perturb_restore_drift_bounded_on_sharded_arena() {
    // the SPSA cycle +ε / −2ε / +ε re-adds identical values per element, so
    // drift stays within the ulp bound the old sequential store guaranteed
    forall("sharded-restore-drift", |g| {
        let mut p = gen_multi_shard(g);
        let orig = p.clone();
        let seed = g.u64();
        let eps = g.f32_in(1e-6, 1e-1);
        p.perturb_trainable(seed, eps);
        p.perturb_trainable(seed, -2.0 * eps);
        p.perturb_trainable(seed, eps);
        let drift = p.max_abs_diff(&orig);
        let bound = 8.0 * f32::EPSILON * (2.0 + 6.0 * eps);
        if drift > bound {
            return Err(format!("drift {drift} > bound {bound} (eps {eps})"));
        }
        Ok(())
    });
}

#[test]
fn prop_zcache_path_bitwise_matches_regeneration() {
    forall("zcache-vs-regen", |g| {
        let mut a = gen_multi_shard(g);
        let mut b = a.clone();
        let seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let quad = |q: &ParamSet| Ok(q.flat().iter().map(|x| x * x).sum::<f32>());
        let mut cache = ZCache::default();
        let ea = spsa::estimate_with(&mut a, seed, eps, quad).map_err(|e| e.to_string())?;
        let eb = spsa::estimate_cached(&mut b, &mut cache, seed, eps, quad)
            .map_err(|e| e.to_string())?;
        if ea.g_scale != eb.g_scale || a.flat() != b.flat() {
            return Err("cached SPSA cycle diverged from regeneration".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fused_step_bitwise_matches_unfused() {
    // θ after (unrestored probes + step_zo_fused) must equal θ after
    // (restored probes + step_zo) bit-for-bit: the fusion only merges
    // sweeps, never changes per-element arithmetic. Covers the three
    // specialized optimizers and one default-impl optimizer, with the
    // z-cache both on and off.
    forall("fused-vs-unfused", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let eps = g.f32_in(1e-5, 1e-2);
        let which = g.usize_in(0, 4);
        let cached = g.bool();
        let mk = |w: usize| -> Box<dyn Optimizer> {
            match w {
                0 => Box::new(Helene::paper_defaults().with_lr(1e-3)),
                1 => Box::new(ZoAdam::new(1e-3, true)),
                2 => Box::new(ZoSgd::new(1e-3)),
                _ => Box::new(ZoSgdMomentum::new(1e-3, 0.9)), // default-impl path
            }
        };
        let quad = |q: &ParamSet| Ok(q.flat().iter().map(|x| x * x).sum::<f32>());

        // unfused: restored probe pair, then the plain step
        let mut p1 = base.clone();
        let mut o1 = mk(which);
        o1.init(&p1);
        let mut c1 = ZCache::default();
        let e1 = if cached {
            spsa::estimate_cached(&mut p1, &mut c1, seed, eps, quad)
        } else {
            spsa::estimate_with(&mut p1, seed, eps, quad)
        }
        .map_err(|e| e.to_string())?;
        if cached {
            o1.step_zo_cached(&mut p1, e1.g_scale, e1.seed, &c1)
        } else {
            o1.step_zo(&mut p1, e1.g_scale, e1.seed)
        }
        .map_err(|e| e.to_string())?;

        // fused: unrestored probe pair, restore folded into the step
        let mut p2 = base.clone();
        let mut o2 = mk(which);
        o2.init(&p2);
        let mut c2 = ZCache::default();
        let e2 = if cached {
            spsa::estimate_cached_unrestored(&mut p2, &mut c2, seed, eps, quad)
        } else {
            spsa::estimate_unrestored(&mut p2, seed, eps, quad)
        }
        .map_err(|e| e.to_string())?;
        let cache_ref = if cached { Some(&c2) } else { None };
        o2.step_zo_fused(&mut p2, e2.g_scale, e2.seed, eps, cache_ref)
            .map_err(|e| e.to_string())?;

        if e1.g_scale != e2.g_scale {
            return Err("probe estimates diverged".into());
        }
        if p1.flat() != p2.flat() {
            return Err(format!(
                "fused != unfused for optimizer {which} (cached={cached})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fused_cycle_bitwise_identical_across_thread_counts() {
    // the fused restore+update sweep keeps the thread-count invariance of
    // the separate sweeps, across 1/2/4/8-worker pools
    forall("fused-thread-invariance", |g| {
        let base = gen_multi_shard(g);
        let seed = g.u64();
        let eps = g.f32_in(1e-4, 1e-2);
        let run = |threads: usize| -> Result<ParamSet, String> {
            let mut p = base.clone();
            let mut opt = Helene::paper_defaults().with_lr(1e-3);
            opt.init(&p);
            let mut cache = ZCache::default();
            with_pool(threads, || -> anyhow::Result<()> {
                let est = spsa::estimate_cached_unrestored(
                    &mut p, &mut cache, seed, eps,
                    |q| Ok(q.flat().iter().map(|x| x * x).sum::<f32>()),
                )?;
                opt.step_zo_fused(&mut p, est.g_scale, est.seed, eps, Some(&cache))
            })
            .map_err(|e| e.to_string())?;
            Ok(p)
        };
        let single = run(1)?;
        for threads in [2, 4, 8] {
            if single.flat() != run(threads)?.flat() {
                return Err(format!("fused cycle differs at {threads} threads"));
            }
        }
        Ok(())
    });
}

#[test]
fn freezing_one_shard_leaves_other_shards_draws_unchanged() {
    // arrays aligned to whole shards: freezing array 0 must not change the
    // z applied to array 1 (position-pure draws)
    let mut all = ParamSet::synthetic(&[SHARD_SIZE, SHARD_SIZE], 1.0);
    let mut partial = all.clone();
    partial.train_mask[0] = false;
    all.perturb_trainable(5, 0.1);
    partial.perturb_trainable(5, 0.1);
    assert_eq!(all.array(1), partial.array(1), "shard 1 draws shifted");
    assert!(partial.array(0).iter().all(|&x| x == 1.0), "frozen shard moved");
}

#[test]
fn helene_full_cycle_identical_between_pools() {
    // several SPSA + step cycles end-to-end under different pools
    let run = |threads: usize| {
        with_pool(threads, || {
            let mut p = ParamSet::synthetic(&[SHARD_SIZE + 7, 3 * SHARD_SIZE / 2], 0.5);
            let mut opt = Helene::paper_defaults().with_lr(3e-3);
            opt.init(&p);
            let mut cache = ZCache::default();
            for s in 0..4 {
                let est = spsa::estimate_cached(&mut p, &mut cache, 100 + s, 1e-3, |q| {
                    Ok(q.flat().iter().map(|x| x * x).sum::<f32>())
                })
                .unwrap();
                opt.step_zo_cached(&mut p, est.g_scale, est.seed, &cache).unwrap();
            }
            p
        })
    };
    let a = run(1);
    let b = run(4);
    let c = run(8);
    assert_eq!(a.flat(), b.flat());
    assert_eq!(b.flat(), c.flat());
}
