//! End-to-end training integration: the full L3→PJRT stack learns.

use helene::model::checkpoint;
use helene::optim::{self, Optimizer};
use helene::runtime::{ModelRunner, Runtime};
use helene::tasks;
use helene::train::{zero_shot_metric, TrainConfig, Trainer};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime loads"))
}

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig { steps, eval_every: steps / 2, eval_examples: 64, ..Default::default() }
}

#[test]
fn fo_adam_solves_sst2_tiny() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 16, 0).unwrap();
    let mut opt = optim::by_name("fo-adam", 1e-2).unwrap();
    let report = Trainer::new(cfg(150)).run(&runner, &data, opt.as_mut()).unwrap();
    assert!(report.test_metric > 0.9, "fo-adam test acc {}", report.test_metric);
    assert!(report.history.final_loss().unwrap() < 0.1);
}

#[test]
fn helene_zo_beats_zero_shot() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 16, 0).unwrap();
    let zs = zero_shot_metric(&runner, &data, tasks::Metric::Accuracy).unwrap();
    let mut opt = optim::by_name("helene", 3e-3).unwrap();
    let report = Trainer::new(cfg(1500)).run(&runner, &data, opt.as_mut()).unwrap();
    assert!(
        report.test_metric > zs + 0.1,
        "helene {} vs zero-shot {zs}",
        report.test_metric
    );
}

#[test]
fn runs_are_reproducible_by_seed() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 8, 1).unwrap();
    let run = || {
        let mut opt = optim::by_name("helene", 1e-3).unwrap();
        Trainer::new(cfg(60)).run(&runner, &data, opt.as_mut()).unwrap()
    };
    let a = run();
    let b = run();
    let la: Vec<f32> = a.history.records.iter().map(|r| r.loss).collect();
    let lb: Vec<f32> = b.history.records.iter().map(|r| r.loss).collect();
    assert_eq!(la, lb, "identical seeds must give identical loss traces");
    assert_eq!(a.test_metric, b.test_metric);
}

#[test]
fn different_seeds_differ() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 8, 1).unwrap();
    let run = |seed: u64| {
        let mut opt = optim::by_name("mezo", 1e-3).unwrap();
        let mut c = cfg(40);
        c.seed = seed;
        Trainer::new(c).run(&runner, &data, opt.as_mut()).unwrap()
    };
    let a = run(0);
    let b = run(123);
    let la: Vec<f32> = a.history.records.iter().map(|r| r.loss).collect();
    let lb: Vec<f32> = b.history.records.iter().map(|r| r.loss).collect();
    assert_ne!(la, lb);
}

#[test]
fn peft_variants_train() {
    // LoRA and prefix tuning move only their adapter params and still learn
    let Some(rt) = runtime() else { return };
    for variant in ["lora", "prefix"] {
        let runner = ModelRunner::new(&rt, "cls-tiny", variant).unwrap();
        let d = runner.spec.dims.clone();
        let data = tasks::generate("sst2", d.vocab, d.max_seq, 16, 0).unwrap();
        let mut params = runner.load_init_params().unwrap();
        let frozen_before: Vec<Vec<f32>> = (0..params.n_arrays())
            .filter(|&i| !params.is_trainable(i))
            .map(|i| params.array(i).to_vec())
            .collect();
        let mut opt = optim::by_name("fo-adam", 1e-2).unwrap();
        let report = Trainer::new(cfg(300))
            .run_with_params(&runner, &data, opt.as_mut(), &mut params)
            .unwrap();
        // rank-2 LoRA / len-2 prefix on a 2-block model: modest but real
        assert!(
            report.test_metric > 0.72,
            "{variant}: test acc {}",
            report.test_metric
        );
        let frozen_after: Vec<Vec<f32>> = (0..params.n_arrays())
            .filter(|&i| !params.is_trainable(i))
            .map(|i| params.array(i).to_vec())
            .collect();
        assert_eq!(frozen_before, frozen_after, "{variant}: frozen params moved");
    }
}

#[test]
fn linear_probing_trains_head_only() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 16, 0).unwrap();
    let mut params = runner.load_init_params().unwrap();
    let embed_before = params.array(0).to_vec();
    let mut opt = optim::by_name("fo-adam", 1e-2).unwrap();
    let mut c = cfg(100);
    c.train_only_layers = Some(vec!["head".to_string()]);
    let report = Trainer::new(c)
        .run_with_params(&runner, &data, opt.as_mut(), &mut params)
        .unwrap();
    assert_eq!(params.array(0), &embed_before[..], "LP must not move the embedding");
    assert!(report.test_metric > 0.55, "LP acc {}", report.test_metric);
}

#[test]
fn cons_post_check_runs_in_loop() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 8, 2).unwrap();
    let mut opt = optim::zo_sgd::ZoSgdCons::new(3e-3);
    let _ = Trainer::new(cfg(150)).run(&runner, &data, &mut opt).unwrap();
    assert_eq!(opt.accepted + opt.reverted, 150, "every step adjudicated");
    assert!(opt.reverted > 0, "some ZO steps should get reverted");
}

#[test]
fn checkpoint_round_trip_resumes_identically() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 8, 5).unwrap();
    let mut params = runner.load_init_params().unwrap();
    let mut opt = optim::by_name("mezo", 1e-3).unwrap();
    let _ = Trainer::new(cfg(30))
        .run_with_params(&runner, &data, opt.as_mut(), &mut params)
        .unwrap();

    let path = std::env::temp_dir().join("helene_e2e_ckpt/ck.bin");
    checkpoint::save(&path, 30, &params, &[]).unwrap();
    let (step, restored, extras) = checkpoint::load(&path, params.spec.clone()).unwrap();
    assert_eq!(step, 30);
    assert!(extras.is_empty());
    assert_eq!(restored.flat(), params.flat());

    // the restored params evaluate identically
    let a = runner.eval_accuracy(&params, &data.test[..32]).unwrap();
    let b = runner.eval_accuracy(&restored, &data.test[..32]).unwrap();
    assert_eq!(a, b);
}

#[test]
fn memory_footprint_matches_paper_c1() {
    // §C.1: HELENE ≈ 3× MeZO (params + m + h); Adam-family 3×; MeZO 1×.
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-small", "ft").unwrap();
    let params = runner.load_init_params().unwrap();
    let psz = params.state_bytes();

    let mut helene = optim::by_name("helene", 1e-3).unwrap();
    helene.init(&params);
    assert_eq!(psz + helene.state_bytes(), 3 * psz);

    let mut mezo = optim::by_name("mezo", 1e-3).unwrap();
    mezo.init(&params);
    assert_eq!(psz + mezo.state_bytes(), psz);

    let mut adam = optim::by_name("zo-adam", 1e-3).unwrap();
    adam.init(&params);
    assert_eq!(psz + adam.state_bytes(), 3 * psz);

    let mut sophia = optim::by_name("zo-sophia", 1e-3).unwrap();
    sophia.init(&params);
    assert_eq!(psz + sophia.state_bytes(), 3 * psz);
}

#[test]
fn forward_grad_trains() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "cls-tiny", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let data = tasks::generate("sst2", d.vocab, d.max_seq, 16, 0).unwrap();
    let mut opt = optim::by_name("forward-grad", 1e-3).unwrap();
    let report = Trainer::new(cfg(300)).run(&runner, &data, opt.as_mut()).unwrap();
    let first_losses: f32 = report.history.records[..20].iter().map(|r| r.loss).sum::<f32>() / 20.0;
    let last = report.history.smoothed_loss(20).unwrap();
    assert!(last < first_losses, "forward-grad loss did not drop: {first_losses} → {last}");
}

#[test]
fn lm_training_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "lm-small", "ft").unwrap();
    let d = runner.spec.dims.clone();
    let corpus = helene::data::corpus::TinyCorpus::new(d.vocab, 4, 0.05, 42);
    let batches = corpus.batches(250, d.batch, d.max_seq, 0);
    let mut opt = optim::by_name("fo-adam", 3e-3).unwrap();
    let tc = TrainConfig::default();
    let hist = helene::train::run_lm(&runner, &batches, opt.as_mut(), &tc).unwrap();
    let first = hist.records[0].loss;
    let last = hist.smoothed_loss(10).unwrap();
    // 250 Adam steps capture the unigram statistics: the loss must drop
    // well below the uniform baseline ln(V), heading towards the corpus'
    // unigram entropy (≈ ½ ln V)
    assert!(
        last < first - 0.8,
        "LM loss did not drop: {first} → {last} (unigram {})",
        corpus.unigram_entropy()
    );
}
