//! Equal-budget convergence harness for the annealed FZOO-style ε
//! adaptation (`--adapt-eps`, DESIGN.md §Adaptive ε).
//!
//! Two fixed-target synthetic tasks — a separable quadratic and a
//! shard-decomposable softmax "synth-LM" — are trained with the
//! one-sided multi-probe protocol over a q ∈ {1, 4, 8} × {fixed ε,
//! adapted ε} grid at a **fixed loss-oracle budget** (steps = B / (q+1),
//! so every cell spends the same number of oracle calls). The curves
//! land in `reports/BENCH_convergence.json`, and the acceptance bar is
//! asserted directly: adapted-ε q = 4 reaches the target loss in no
//! more oracle calls than fixed-ε q = 1 spends in the whole budget.
//!
//! The quadratic is the discriminating task: its one-sided estimator
//! bias grows with ε·tr(H), so a fixed large ε plateaus above the
//! target while the adapted schedule — which anneals ε exactly when the
//! probe scalars turn consistent (bias-dominated) — descends through
//! it. The softmax task has tr(H) < 1, so ε barely matters there; it
//! pins that adaptation never *hurts* a well-conditioned loss.
//!
//! Everything is deterministic (seeded z-streams, canonical folds), so
//! the same harness also pins the adapted trajectories bitwise across
//! rayon thread counts, both storage codecs, and N ∈ {1, 2, 4}
//! distributed workers against the single-process reference.

use std::collections::BTreeMap;
use std::ops::Range;
use std::path::PathBuf;

use helene::dist::{
    Coordinator, DistConfig, FaultPlan, ShardLossOracle, WorkerFactory,
};
use helene::model::params::{Codec, ParamSet, SHARD_SIZE};
use helene::optim::spsa::{bf16_eps_floor, fold_partial_losses, EpsAdaptConfig};
use helene::optim::zo_sgd::ZoSgd;
use helene::optim::Optimizer;
use helene::train::{TrainConfig, ZoProtocol};
use helene::util::json::Json;
use helene::util::rng::mix64;

/// Run seed for every trajectory in this harness.
const RUN_SEED: u64 = 7;
/// Starting probe radius ε₀ shared by the fixed and adapted cells.
const EPS0: f32 = 0.05;
/// ZO-SGD learning rate (per-task below).
const QUAD_LR: f32 = 0.002;
const LM_LR: f32 = 0.5;
/// Oracle-call budgets and target losses (picked so the fixed-ε q = 1
/// quadratic cell plateaus well above its target — ~43 vs 8 — while the
/// adapted q = 4 cell reaches it in under half the budget).
const QUAD_BUDGET: usize = 6000;
const QUAD_TARGET: f32 = 8.0;
const LM_BUDGET: usize = 1200;
const LM_TARGET: f32 = 1.0;

/// Fixed-target separable quadratic: `Σⱼ (θⱼ − tⱼ)²` with a
/// deterministic per-element target in `[-0.25, 0.25)`. Unlike
/// `SepQuadOracle` the target does NOT move with the step, so the loss
/// has a fixed minimum and a run can converge to it.
#[derive(Clone)]
struct FixedQuadOracle;

impl FixedQuadOracle {
    fn target(j: usize) -> f32 {
        let h = mix64(0x5EED_7A26, j as u64);
        ((h % 2048) as f32 / 2048.0 - 0.5) * 0.5
    }
}

impl ShardLossOracle for FixedQuadOracle {
    fn shard_partials(
        &mut self,
        params: &ParamSet,
        shards: Range<usize>,
        _step: u64,
    ) -> anyhow::Result<Vec<f64>> {
        let flat = params.flat_f32();
        let n = flat.len();
        let mut out = Vec::with_capacity(shards.len());
        for s in shards {
            let lo = s * SHARD_SIZE;
            anyhow::ensure!(lo < n, "shard {s} out of range for {n} params");
            let hi = ((s + 1) * SHARD_SIZE).min(n);
            let mut sum = 0.0f64;
            for (j, &x) in flat[lo..hi].iter().enumerate() {
                let d = (x - Self::target(lo + j)) as f64;
                sum += d * d;
            }
            out.push(sum);
        }
        Ok(out)
    }
}

/// Shard-decomposable softmax "synth-LM": each shard's span is one
/// V-way logit vector with a fixed target class, and the shard partial
/// is its cross-entropy `logΣⱼ exp(xⱼ) − x_target` (numerically stable
/// two-pass log-sum-exp, f64 in element order). Smooth, convex per
/// shard, bounded below by 0, with a softmax Hessian of trace < 1 — the
/// ε-insensitive counterpart to the quadratic.
#[derive(Clone)]
struct SoftmaxLmOracle;

impl ShardLossOracle for SoftmaxLmOracle {
    fn shard_partials(
        &mut self,
        params: &ParamSet,
        shards: Range<usize>,
        _step: u64,
    ) -> anyhow::Result<Vec<f64>> {
        let flat = params.flat_f32();
        let n = flat.len();
        let mut out = Vec::with_capacity(shards.len());
        for s in shards {
            let lo = s * SHARD_SIZE;
            anyhow::ensure!(lo < n, "shard {s} out of range for {n} params");
            let hi = ((s + 1) * SHARD_SIZE).min(n);
            let span = &flat[lo..hi];
            let target = (mix64(0xC0FF_EE00, s as u64) as usize) % span.len();
            let mut max = f64::NEG_INFINITY;
            for &x in span {
                max = max.max(x as f64);
            }
            let mut sum = 0.0f64;
            for &x in span {
                sum += (x as f64 - max).exp();
            }
            out.push(max + sum.ln() - span[target] as f64);
        }
        Ok(out)
    }
}

/// One trajectory of the single-process multi-probe protocol.
struct RunResult {
    /// Baseline loss L(θ) at the top of each step.
    losses: Vec<f32>,
    /// The ε each step's probes used.
    eps_trace: Vec<f32>,
    /// Final arena.
    params: ParamSet,
    /// Oracle calls consumed when the baseline first hit the target
    /// (`None` = never within budget).
    calls_to_target: Option<usize>,
}

/// Drive `ZoProtocol` (fixed or adapted ε) over a shard-decomposable
/// oracle for `steps` steps of `q` probes, counting oracle calls. Every
/// step costs exactly q + 1 calls (one shared baseline + q probes).
fn run_single(
    base: &ParamSet,
    mut oracle: impl ShardLossOracle,
    lr: f32,
    q: usize,
    adapt: bool,
    steps: usize,
    target: Option<f32>,
) -> RunResult {
    let n_shards = base.n_shards();
    let cfg = TrainConfig {
        steps,
        spsa_eps: EPS0,
        seed: RUN_SEED,
        probes: q,
        adapt_eps: adapt.then(EpsAdaptConfig::default),
        ..Default::default()
    };
    let mut opt = ZoSgd::new(lr);
    opt.init(base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new_adapted(&cfg, bf16_eps_floor(base)).unwrap();
    let mut losses = Vec::with_capacity(steps);
    let mut eps_trace = Vec::with_capacity(steps);
    let mut calls_to_target = None;
    for step in 1..=steps {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        let boundary = step == steps;
        eps_trace.push(proto.eps());
        let est = proto
            .step_multi(&mut opt, &mut params, step_seed, next_seed, boundary, |p| {
                Ok(fold_partial_losses(oracle.shard_partials(p, 0..n_shards, step as u64)?))
            })
            .unwrap();
        losses.push(est.loss());
        if calls_to_target.is_none() {
            if let Some(t) = target {
                if est.loss() <= t {
                    // the baseline eval was call (step-1)(q+1) + 1
                    calls_to_target = Some((step - 1) * (q + 1) + 1);
                }
            }
        }
    }
    RunResult { losses, eps_trace, params, calls_to_target }
}

/// A 256-element single-shard arena (θ₀ = 0.5 everywhere): small enough
/// that the O(n/q) zeroth-order convergence horizon fits the budget.
fn small_arena() -> ParamSet {
    ParamSet::synthetic(&[256], 0.5)
}

/// One grid cell's summary for the JSON report.
struct Cell {
    q: usize,
    adapt: bool,
    steps: usize,
    final_loss: f32,
    best_loss: f32,
    calls_to_target: Option<usize>,
    eps_final: f32,
}

fn run_grid(
    oracle: &(impl ShardLossOracle + Clone),
    lr: f32,
    budget: usize,
    target: f32,
) -> Vec<Cell> {
    let base = small_arena();
    let mut cells = Vec::new();
    for q in [1usize, 4, 8] {
        for adapt in [false, true] {
            let steps = budget / (q + 1);
            let r = run_single(&base, oracle.clone(), lr, q, adapt, steps, Some(target));
            let best = r.losses.iter().copied().fold(f32::INFINITY, f32::min);
            cells.push(Cell {
                q,
                adapt,
                steps,
                final_loss: *r.losses.last().unwrap(),
                best_loss: best,
                calls_to_target: r.calls_to_target,
                eps_final: *r.eps_trace.last().unwrap(),
            });
        }
    }
    cells
}

fn cells_to_json(cells: &[Cell], budget: usize, target: f32) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("budget_calls".to_string(), Json::Num(budget as f64));
    obj.insert("target_loss".to_string(), Json::Num(target as f64));
    let mut grid = BTreeMap::new();
    for c in cells {
        let mut o = BTreeMap::new();
        o.insert("steps".to_string(), Json::Num(c.steps as f64));
        o.insert("final_loss".to_string(), Json::Num(c.final_loss as f64));
        o.insert("best_loss".to_string(), Json::Num(c.best_loss as f64));
        o.insert(
            "calls_to_target".to_string(),
            match c.calls_to_target {
                Some(n) => Json::Num(n as f64),
                None => Json::Null,
            },
        );
        o.insert("eps_final".to_string(), Json::Num(c.eps_final as f64));
        let tag = if c.adapt { "adapt" } else { "fixed" };
        grid.insert(format!("q{}_{}", c.q, tag), Json::Obj(o));
    }
    obj.insert("grid".to_string(), Json::Obj(grid));
    Json::Obj(obj)
}

fn cell(cells: &[Cell], q: usize, adapt: bool) -> &Cell {
    cells.iter().find(|c| c.q == q && c.adapt == adapt).unwrap()
}

#[test]
fn equal_budget_grid_meets_the_acceptance_bar_and_writes_bench_json() {
    let quad = run_grid(&FixedQuadOracle, QUAD_LR, QUAD_BUDGET, QUAD_TARGET);
    let lm = run_grid(&SoftmaxLmOracle, LM_LR, LM_BUDGET, LM_TARGET);

    // the acceptance bar: adapted-ε q = 4 reaches the target in no more
    // oracle calls than fixed-ε q = 1 (censored at the budget when a
    // cell never reaches it — the fixed quadratic cell plateaus above)
    let adapted_q4 = cell(&quad, 4, true)
        .calls_to_target
        .expect("adapted q=4 must reach the quadratic target within budget");
    let fixed_q1 = cell(&quad, 1, false).calls_to_target.unwrap_or(QUAD_BUDGET);
    assert!(
        adapted_q4 <= fixed_q1,
        "adapted q=4 took {adapted_q4} oracle calls to the target, \
         fixed q=1 took {fixed_q1}"
    );
    // annealing must beat the fixed plateau at the same probe count too
    assert!(
        cell(&quad, 4, true).best_loss < cell(&quad, 4, false).best_loss,
        "adapted q=4 best {} is not below the fixed q=4 plateau {}",
        cell(&quad, 4, true).best_loss,
        cell(&quad, 4, false).best_loss
    );
    // and the adapted schedules really moved ε (downward from ε₀ here)
    for q in [1usize, 4] {
        let e = cell(&quad, q, true).eps_final;
        assert!(e < EPS0, "quad q={q}: adapted ε never annealed ({e} vs {EPS0})");
    }
    // the well-conditioned softmax task converges in every cell —
    // adaptation must never break a loss that doesn't need it
    for c in &lm {
        assert!(
            c.calls_to_target.is_some(),
            "lm q={} {}: never reached {LM_TARGET} (best {})",
            c.q,
            if c.adapt { "adapt" } else { "fixed" },
            c.best_loss
        );
    }

    let mut root = BTreeMap::new();
    root.insert("quadratic".to_string(), cells_to_json(&quad, QUAD_BUDGET, QUAD_TARGET));
    root.insert("synth_lm".to_string(), cells_to_json(&lm, LM_BUDGET, LM_TARGET));
    root.insert(
        "adapted_q4_beats_fixed_q1".to_string(),
        Json::Bool(adapted_q4 <= fixed_q1),
    );
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("reports")
        .join("BENCH_convergence.json");
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, Json::Obj(root).to_string()).unwrap();
}

/// Run `f` inside a dedicated rayon pool of `threads` workers.
fn with_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("pool")
        .install(f)
}

#[test]
fn adapted_trajectories_are_bitwise_across_thread_counts_in_both_codecs() {
    // the ε schedule is a pure function of (ε bits, probe-scalar bits),
    // and the probe scalars come out of the canonical fold — so the
    // whole adapted trajectory must be invariant under the rayon pool
    // size, in both storage codecs
    for codec in [Codec::F32, Codec::Bf16] {
        let base = small_arena().with_codec(codec);
        let run = |threads: usize| {
            with_pool(threads, || {
                run_single(&base, FixedQuadOracle, QUAD_LR, 4, true, 40, None)
            })
        };
        let reference = run(1);
        for threads in [2usize, 4, 8] {
            let tag = format!("{}/threads={threads}", codec.name());
            let got = run(threads);
            for (i, (a, b)) in got.losses.iter().zip(&reference.losses).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{tag}: loss diverges at step {}",
                    i + 1
                );
            }
            for (i, (a, b)) in got.eps_trace.iter().zip(&reference.eps_trace).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: ε diverges at step {}", i + 1);
            }
            assert!(got.params.bits_eq(&reference.params), "{tag}: final params diverge");
        }
        // and the adapted trace really adapted
        assert!(
            reference.eps_trace.windows(2).any(|w| w[0].to_bits() != w[1].to_bits()),
            "{}: ε never moved",
            codec.name()
        );
    }
}

#[test]
fn adapted_dist_runs_match_the_single_process_reference_on_the_convergence_task() {
    // the same convergence oracle through the distributed tier: N
    // workers over a multi-shard arena (real span cuts) must reproduce
    // the single-process adapted trajectory bitwise — losses, committed
    // ε trace, and final arena
    let steps = 6usize;
    let base = ParamSet::synthetic(&[3 * SHARD_SIZE, 2 * SHARD_SIZE], 0.5);
    let n_shards = base.n_shards();
    let q = 4usize;
    let cfg = TrainConfig {
        steps,
        spsa_eps: EPS0,
        seed: RUN_SEED,
        probes: q,
        adapt_eps: Some(EpsAdaptConfig::default()),
        ..Default::default()
    };
    let mut oracle = FixedQuadOracle;
    let mut opt = ZoSgd::new(QUAD_LR);
    opt.init(&base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new_adapted(&cfg, bf16_eps_floor(&base)).unwrap();
    let mut ref_losses = Vec::new();
    let mut ref_eps = Vec::new();
    for step in 1..=steps {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        ref_eps.push(proto.eps());
        let est = proto
            .step_multi(&mut opt, &mut params, step_seed, next_seed, step == steps, |p| {
                Ok(fold_partial_losses(oracle.shard_partials(p, 0..n_shards, step as u64)?))
            })
            .unwrap();
        ref_losses.push(est.loss());
    }

    for workers in [1usize, 2, 4] {
        let tag = format!("workers={workers}");
        let dcfg = DistConfig {
            workers,
            eps: EPS0,
            probes: q,
            adapt: Some(EpsAdaptConfig::default()),
            fault_plan: FaultPlan::new(),
            ..Default::default()
        };
        let factory: WorkerFactory = Box::new(|_slot| {
            Ok((
                Box::new(FixedQuadOracle) as Box<dyn ShardLossOracle>,
                Box::new(ZoSgd::new(QUAD_LR)) as Box<dyn Optimizer>,
            ))
        });
        let mut coord = Coordinator::launch_threads(dcfg, base.clone(), factory).unwrap();
        let report = coord.run(steps, RUN_SEED).unwrap();
        assert_eq!(report.losses.len(), ref_losses.len(), "{tag}: step count");
        for (i, (a, b)) in report.losses.iter().zip(&ref_losses).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: loss diverges at step {}", i + 1);
        }
        for (i, rec) in report.log.iter().enumerate() {
            assert_eq!(
                rec.eps.to_bits(),
                ref_eps[i].to_bits(),
                "{tag}: committed ε diverges at step {}",
                i + 1
            );
        }
        assert!(report.params.bits_eq(&params), "{tag}: final params diverge");
    }
}
