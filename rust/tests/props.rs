//! Property tests on coordinator invariants (hand-rolled proptest-lite,
//! see `util::prop`). These don't need artifacts — they exercise the pure
//! ZO machinery over randomized layouts, seeds, and hyper-parameters.

use helene::model::manifest::{ModelDims, ModelKind, ParamInfo, VariantSpec};
use helene::model::params::ParamSet;
use helene::optim::clip::ClipPolicy;
use helene::optim::helene::{Helene, HeleneConfig, MomentumMode};
use helene::optim::sophia::ZoSophia;
use helene::optim::zo_sgd::ZoSgd;
use helene::optim::{spsa, Optimizer};
use helene::util::prop::{forall, Gen};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Random ParamSet: 1-5 layer groups of random sizes/values.
fn gen_params(g: &mut Gen) -> ParamSet {
    let n_layers = g.usize_in(1, 6);
    let mut params = Vec::new();
    let mut offset = 0;
    for i in 0..n_layers {
        let size = g.usize_in(1, 200);
        params.push(ParamInfo {
            name: format!("p{i}"),
            shape: vec![size],
            layer: format!("layer{}", i / 2),
            trainable: true,
            offset,
            size,
        });
        offset += size;
    }
    let spec = Arc::new(VariantSpec {
        model: "prop".into(),
        variant: "ft".into(),
        kind: ModelKind::Cls,
        dims: ModelDims {
            vocab: 8, d_model: 4, n_heads: 1, n_layers: 1, d_ff: 4,
            max_seq: 4, n_classes: 2, batch: 2, lora_rank: 1, prefix_len: 1,
        },
        params_bin: "none".into(),
        n_params: offset,
        codec: helene::model::params::Codec::F32,
        params: params.clone(),
        entrypoints: BTreeMap::new(),
    });
    let arrays = params.iter().map(|p| g.vec_f32(p.size, -2.0, 2.0)).collect();
    ParamSet::from_arrays(spec, arrays)
}

#[test]
fn prop_perturb_restore_drift_bounded() {
    forall("perturb-restore-drift", |g| {
        let mut p = gen_params(g);
        let orig = p.clone();
        let seed = g.u64();
        let eps = g.f32_in(1e-6, 1e-1);
        // the SPSA cycle: +ε, −2ε, +ε
        p.perturb_trainable(seed, eps);
        p.perturb_trainable(seed, -2.0 * eps);
        p.perturb_trainable(seed, eps);
        let drift = p.max_abs_diff(&orig);
        // drift bounded by a few ulps of the (value + perturbation) scale
        let bound = 8.0 * f32::EPSILON * (2.0 + 6.0 * eps);
        if drift > bound {
            return Err(format!("drift {drift} > bound {bound} (eps {eps})"));
        }
        Ok(())
    });
}

#[test]
fn prop_spsa_estimates_quadratic_gradient() {
    // for L = ½‖θ‖², zᵀ∇L is recovered to O(ε) for any seed/layout
    forall("spsa-quadratic", |g| {
        let mut p = gen_params(g);
        let seed = g.u64();
        let eps = 1e-4f32;
        let mut loss_mag = 0f32;
        let est = spsa::estimate_with(&mut p, seed, eps, |q| {
            // accumulate in f64 so the property tests SPSA itself, not the
            // oracle's sequential f32 summation error
            let l = 0.5 * q
                .flat()
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>() as f32;
            loss_mag = loss_mag.max(l);
            Ok(l)
        })
        .map_err(|e| e.to_string())?;
        let mut proj = 0f64;
        p.visit_z(seed, |i, z| {
            for (x, zv) in p.array(i).iter().zip(z) {
                proj += (*x as f64) * (*zv as f64);
            }
        });
        // error floor: f32 cancellation in (L⁺ − L⁻) is ~ulp(L)/2ε
        let cancel = (loss_mag * f32::EPSILON) as f64 / (2.0 * eps as f64);
        let tol = 0.02 * proj.abs().max(1.0) + 8.0 * cancel;
        let err = (est.g_scale as f64 - proj).abs();
        if err > tol {
            return Err(format!("spsa {} vs proj {proj} (tol {tol})", est.g_scale));
        }
        Ok(())
    });
}

#[test]
fn prop_helene_step_bounded_by_lambda_floor() {
    // the preconditioner denominator is ≥ γλ + ε, so the per-element step
    // is ≤ lr·|m|/(γλ) (+ weight-decay term); with fresh state |m| ≤ α|g|.
    forall("helene-step-bound", |g| {
        let mut p = gen_params(g);
        let before = p.clone();
        let lam = g.f32_in(0.1, 3.0);
        let lr = g.f32_in(1e-5, 1e-2);
        let g_scale = g.f32_in(-2.0, 2.0);
        let mut opt = Helene::new(HeleneConfig {
            lr,
            clip: ClipPolicy::Constant(lam),
            weight_decay: 0.0,
            gamma: 1.0,
            ..Default::default()
        });
        opt.init(&p);
        let seed = g.u64();
        opt.step_zo(&mut p, g_scale, seed).map_err(|e| e.to_string())?;
        // bound per element: |Δθ| ≤ lr·|α·g_scale·z|/λ with α ≤ 1
        let mut max_viol = 0f32;
        before.visit_z(seed, |i, z| {
            for (j, zv) in z.iter().enumerate() {
                let step = (p.array(i)[j] - before.array(i)[j]).abs();
                let bound = lr * (g_scale * zv).abs() / lam * 1.01 + 1e-7;
                if step > bound {
                    max_viol = max_viol.max(step - bound);
                }
            }
        });
        if max_viol > 0.0 {
            return Err(format!("step exceeded λ-floor bound by {max_viol}"));
        }
        Ok(())
    });
}

#[test]
fn prop_layer_scaled_lambda_decreases_with_width() {
    forall("lambda-monotone", |g| {
        let d1 = g.usize_in(1, 1000);
        let d2 = d1 + g.usize_in(1, 1000);
        let r = g.f32_in(0.01, 10.0);
        let l = ClipPolicy::LayerScaled { r }
            .lambdas(&[d1, d2])
            .map_err(|e| e.to_string())?;
        if l[0] < l[1] {
            return Err(format!("λ({d1})={} < λ({d2})={}", l[0], l[1]));
        }
        Ok(())
    });
}

#[test]
fn prop_sophia_update_magnitude_clipped() {
    forall("sophia-clip", |g| {
        let mut p = gen_params(g);
        let before = p.clone();
        let lr = g.f32_in(1e-5, 1e-2);
        let mut opt = ZoSophia::new(lr);
        opt.init(&p);
        let steps = g.usize_in(1, 5);
        for s in 0..steps {
            opt.step_zo(&mut p, g.f32_in(-3.0, 3.0), g.u64().wrapping_add(s as u64))
                .map_err(|e| e.to_string())?;
        }
        let max_step = p.max_abs_diff(&before);
        let bound = steps as f32 * lr * opt.rho * 10.0 + 1e-6;
        if max_step > bound {
            return Err(format!("sophia moved {max_step} > {bound}"));
        }
        Ok(())
    });
}

#[test]
fn prop_zo_sgd_is_exact_seeded_axpy() {
    forall("zo-sgd-axpy", |g| {
        let mut p = gen_params(g);
        let mut q = p.clone();
        let lr = g.f32_in(1e-6, 1e-1);
        let gs = g.f32_in(-5.0, 5.0);
        let seed = g.u64();
        let mut opt = ZoSgd::new(lr);
        opt.init(&p);
        opt.step_zo(&mut p, gs, seed).map_err(|e| e.to_string())?;
        q.perturb_trainable(seed, -lr * gs);
        if p.max_abs_diff(&q) != 0.0 {
            return Err("zo-sgd diverged from manual axpy".into());
        }
        Ok(())
    });
}

#[test]
fn prop_update_ignores_frozen_arrays() {
    forall("frozen-untouched", |g| {
        let mut p = gen_params(g);
        // freeze a random prefix of arrays
        let k = g.usize_in(0, p.n_arrays());
        for i in 0..k {
            p.train_mask[i] = false;
        }
        let before = p.clone();
        let mut opt = Helene::paper_defaults().with_lr(1e-2);
        opt.init(&p);
        opt.step_zo(&mut p, g.f32_in(-2.0, 2.0), g.u64())
            .map_err(|e| e.to_string())?;
        for i in 0..k {
            if p.array(i) != before.array(i) {
                return Err(format!("frozen array {i} moved"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_momentum_modes_all_descend_on_quadratic() {
    // every HELENE momentum mode reduces ‖θ‖ on L = ½‖θ‖² when driven by
    // exact SPSA estimates (descent sanity across the ablation ladder)
    forall("modes-descend", |g| {
        let mode = match g.usize_in(0, 4) {
            0 => MomentumMode::None,
            1 => MomentumMode::Ema,
            2 => MomentumMode::Biased,
            _ => MomentumMode::Annealed,
        };
        let mut p = gen_params(g);
        let norm0: f64 = p.flat().iter().map(|&x| (x as f64).powi(2)).sum();
        if norm0 < 1e-6 {
            return Ok(());
        }
        let mut opt = Helene::paper_defaults().with_lr(5e-3).with_momentum(mode);
        opt.init(&p);
        for s in 0..100 {
            let est = spsa::estimate_with(&mut p, 1000 + s, 1e-4, |q| {
                Ok(0.5 * q.flat().iter().map(|x| x * x).sum::<f32>())
            })
            .map_err(|e| e.to_string())?;
            opt.step_zo(&mut p, est.g_scale, est.seed).map_err(|e| e.to_string())?;
        }
        let norm1: f64 = p.flat().iter().map(|&x| (x as f64).powi(2)).sum();
        if norm1 >= norm0 {
            return Err(format!("{mode:?}: ‖θ‖² {norm0} → {norm1} did not descend"));
        }
        Ok(())
    });
}
