//! Property suite for the fault-tolerant distributed tier (`helene::dist`):
//! faulted multi-worker runs must end **bitwise identical** (f32 arenas)
//! to the unfaulted single-worker `ZoProtocol` — per-step loss trace and
//! final parameters both — and a replacement rebuilt purely from the
//! commit log must match the surviving replicas exactly. The multi-probe
//! grid (`probes` = q > 1) is held to the same bar against
//! `ZoProtocol::step_multi`.
//!
//! No artifacts needed: the tier runs against the synthetic separable
//! [`SepQuadOracle`], which is pure and shard-decomposable by
//! construction.

use std::ops::Range;
use std::time::Duration;

use helene::dist::{
    Coordinator, DistConfig, DistReport, FaultPlan, SepQuadOracle, ShardLossOracle,
    WorkerFactory,
};
use helene::model::checkpoint::{self, CommitRecord, SeedRecord};
use helene::model::params::{Codec, ParamSet, SHARD_SIZE};
use helene::optim::helene::Helene;
use helene::optim::spsa::{bf16_eps_floor, fold_partial_losses, EpsAdaptConfig};
use helene::optim::zo_sgd::ZoSgd;
use helene::optim::Optimizer;
use helene::train::{TrainConfig, ZoProtocol};
use helene::util::rng::mix64;

const STEPS: usize = 6;
const RUN_SEED: u64 = 11;
const EPS: f32 = 1e-3;
const LR: f32 = 0.01;

fn base_params() -> ParamSet {
    // 5 shards across two layer groups: enough spans that 2- and 4-worker
    // runs really dispatch disjoint assignments (faults keyed to worker 1
    // must be able to fire at probe time), with a layer boundary for span
    // planning to snap to
    ParamSet::synthetic(&[3 * SHARD_SIZE, 2 * SHARD_SIZE], 0.5)
}

fn factory() -> WorkerFactory {
    Box::new(|_slot| {
        Ok((
            Box::new(SepQuadOracle::new()) as Box<dyn ShardLossOracle>,
            Box::new(ZoSgd::new(LR)) as Box<dyn Optimizer>,
        ))
    })
}

fn dist_cfg(workers: usize, plan: FaultPlan) -> DistConfig {
    DistConfig {
        workers,
        eps: EPS,
        // small waves keep the fault tests fast; the delay fault below is
        // scheduled well past this deadline
        timeout: Duration::from_millis(40),
        retry_budget: 3,
        recover: true,
        fault_plan: plan,
        seed_log: None,
        probes: 1,
        wave_backoff: None,
        adapt: None,
    }
}

/// The unfaulted single-worker reference: the default-config (pipelined)
/// `ZoProtocol` over the same oracle, totalling the loss through the same
/// canonical per-shard fold the coordinator uses.
fn reference_run() -> (Vec<f32>, ParamSet) {
    let base = base_params();
    let n_shards = base.n_shards();
    let mut oracle = SepQuadOracle::new();
    let cfg = TrainConfig { steps: STEPS, spsa_eps: EPS, seed: RUN_SEED, ..Default::default() };
    let mut opt = ZoSgd::new(LR);
    opt.init(&base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new(&cfg);
    let mut losses = Vec::with_capacity(STEPS);
    // mirror the trainer's step loop: the trainer tracks the step number,
    // so thread it into the oracle from the enclosing scope
    for step in 1..=STEPS {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        let boundary = step == STEPS;
        let est = proto
            .step(&mut opt, &mut params, step_seed, next_seed, boundary, |p| {
                Ok(fold_partial_losses(
                    oracle.shard_partials(p, 0..n_shards, step as u64)?,
                ))
            })
            .unwrap();
        losses.push(est.loss());
    }
    proto.finish(&mut params);
    (losses, params)
}

/// The single-process multi-probe reference: the default-config
/// (pipelined) `ZoProtocol::step_multi` over the same oracle. The final
/// step runs as a `boundary` (update only, no prefetch), which makes the
/// cumulative per-element op sequence identical to the distributed
/// apply path — step k's prefetch sweep in the pipeline is step k+1's
/// opening walk sweep in the tier.
fn reference_run_multi(q: usize) -> (Vec<f32>, ParamSet) {
    let base = base_params();
    let n_shards = base.n_shards();
    let mut oracle = SepQuadOracle::new();
    let cfg = TrainConfig {
        steps: STEPS,
        spsa_eps: EPS,
        seed: RUN_SEED,
        probes: q,
        ..Default::default()
    };
    let mut opt = ZoSgd::new(LR);
    opt.init(&base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new(&cfg);
    let mut losses = Vec::with_capacity(STEPS);
    for step in 1..=STEPS {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        let boundary = step == STEPS;
        let est = proto
            .step_multi(&mut opt, &mut params, step_seed, next_seed, boundary, |p| {
                Ok(fold_partial_losses(
                    oracle.shard_partials(p, 0..n_shards, step as u64)?,
                ))
            })
            .unwrap();
        losses.push(est.loss());
    }
    (losses, params)
}

fn run_dist(cfg: DistConfig) -> (Coordinator<helene::dist::ChannelTransport>, DistReport) {
    let mut coord = Coordinator::launch_threads(cfg, base_params(), factory()).unwrap();
    let report = coord.run(STEPS, RUN_SEED).unwrap();
    (coord, report)
}

/// Launch and drive the multi-probe grid directly (valid for any q ≥ 1,
/// so the q = 1 multi semantics get coverage too — `run()` only
/// delegates when `probes > 1`).
fn run_dist_multi(cfg: DistConfig) -> (Coordinator<helene::dist::ChannelTransport>, DistReport) {
    let mut coord = Coordinator::launch_threads(cfg, base_params(), factory()).unwrap();
    let report = coord.run_multi(STEPS, RUN_SEED).unwrap();
    (coord, report)
}

fn assert_bitwise(tag: &str, report: &DistReport, ref_losses: &[f32], ref_params: &ParamSet) {
    assert_eq!(report.losses.len(), ref_losses.len(), "{tag}: step count");
    for (i, (a, b)) in report.losses.iter().zip(ref_losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{tag}: loss trace diverges at step {} ({a} vs {b})",
            i + 1
        );
    }
    assert!(report.params.bits_eq(ref_params), "{tag}: final params diverge");
}

#[test]
fn unfaulted_runs_match_the_single_worker_protocol_for_any_worker_count() {
    let (ref_losses, ref_params) = reference_run();
    for workers in [1usize, 2, 4] {
        let (mut coord, report) = run_dist(dist_cfg(workers, FaultPlan::new()));
        assert_bitwise(&format!("workers={workers}"), &report, &ref_losses, &ref_params);
        assert_eq!(report.workers_alive, workers);
        assert_eq!(report.stats.deaths, 0);
        // every replica holds the identical arena
        for (w, replica) in coord.fetch_all().unwrap() {
            assert!(replica.bits_eq(&ref_params), "workers={workers}: replica {w} diverges");
        }
        // the committed log replays to the same parameters from step 0
        let replayed =
            helene::dist::replay_commit_log(&base_params(), &mut ZoSgd::new(LR), &report.log)
                .unwrap();
        assert!(replayed.bits_eq(&ref_params), "workers={workers}: replay diverges");
    }
}

#[test]
fn multi_probe_runs_match_the_single_process_step_multi() {
    // the tentpole invariant: the (q + 1) × spans probe grid, folded per
    // point in canonical shard order and applied via multi-records, is
    // bitwise the single-process multi-probe pipeline — for any worker
    // count and any q (q = 1 exercises the degenerate grid)
    for q in [1usize, 4] {
        let (ref_losses, ref_params) = reference_run_multi(q);
        for workers in [1usize, 2, 4] {
            let tag = format!("q={q}/workers={workers}");
            let mut cfg = dist_cfg(workers, FaultPlan::new());
            cfg.probes = q;
            let (mut coord, report) = run_dist_multi(cfg);
            assert_bitwise(&tag, &report, &ref_losses, &ref_params);
            assert_eq!(report.workers_alive, workers);
            // every record is a q-probe multi commit with probe 0 on the
            // step seed (the prefetch-compatibility contract)
            for (i, rec) in report.log.iter().enumerate() {
                assert!(!rec.pairwise, "{tag}: record {i} is pairwise");
                assert_eq!(rec.probes.len(), q, "{tag}: record {i} probe count");
                assert_eq!(
                    rec.probes[0].0,
                    mix64(RUN_SEED, i as u64 + 1),
                    "{tag}: record {i} probe 0 is not the step seed"
                );
            }
            for (w, replica) in coord.fetch_all().unwrap() {
                assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
            }
            let replayed = helene::dist::replay_commit_log(
                &base_params(),
                &mut ZoSgd::new(LR),
                &report.log,
            )
            .unwrap();
            assert!(replayed.bits_eq(&ref_params), "{tag}: replay diverges");
        }
    }
}

#[test]
fn faulted_multi_probe_runs_stay_bitwise_identical_and_recover() {
    // worker-class faults against the probe grid: a death mid-step (the
    // replacement rebuilds by replaying v2 multi-records), a dropped and
    // a delayed reply, and a poisoned partial — all invisible in the
    // committed trajectory
    let plans =
        [("death", "die@3:1"), ("drop+delay", "drop@2:0,delay@4:1:200"), ("nan", "nan@2:1")];
    for q in [1usize, 4] {
        let (ref_losses, ref_params) = reference_run_multi(q);
        for (name, spec) in plans {
            for workers in [2usize, 4] {
                let tag = format!("{name}/q={q}/workers={workers}");
                let mut cfg = dist_cfg(workers, FaultPlan::parse(spec).unwrap());
                cfg.probes = q;
                let (mut coord, report) = run_dist_multi(cfg);
                assert_bitwise(&tag, &report, &ref_losses, &ref_params);
                if name == "death" {
                    assert!(report.stats.deaths >= 1, "{tag}: no death recorded");
                    assert!(report.stats.recoveries >= 1, "{tag}: no recovery recorded");
                    assert_eq!(report.workers_alive, workers, "{tag}: quorum not restored");
                } else {
                    assert!(report.stats.retries >= 1, "{tag}: fault never cost a retry");
                }
                for (w, replica) in coord.fetch_all().unwrap() {
                    assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
                }
                let replayed = helene::dist::replay_commit_log(
                    &base_params(),
                    &mut ZoSgd::new(LR),
                    &report.log,
                )
                .unwrap();
                assert!(replayed.bits_eq(&ref_params), "{tag}: replay diverges");
            }
        }
    }
}

#[test]
fn clip_telemetry_is_reported_and_identical_across_replicas() {
    // HELENE's clip_fraction was previously invisible to `helene dist`;
    // now every Applied reply carries it, and since every replica runs
    // the identical apply arithmetic — including a seed-log-rebuilt
    // replacement — the reported fractions must agree exactly
    let helene_factory: WorkerFactory = Box::new(|_slot| {
        Ok((
            Box::new(SepQuadOracle::new()) as Box<dyn ShardLossOracle>,
            Box::new(Helene::paper_defaults().with_lr(LR)) as Box<dyn Optimizer>,
        ))
    });
    let mut cfg = dist_cfg(3, FaultPlan::parse("die@3:1").unwrap());
    cfg.probes = 4;
    let mut coord = Coordinator::launch_threads(cfg, base_params(), helene_factory).unwrap();
    let report = coord.run_multi(STEPS, RUN_SEED).unwrap();
    assert_eq!(report.clip_fractions.len(), 3);
    let first = report.clip_fractions[0].expect("helene reports a clip fraction");
    for (w, c) in report.clip_fractions.iter().enumerate() {
        let c = c.unwrap_or_else(|| panic!("worker {w} reported no clip fraction"));
        assert_eq!(c.to_bits(), first.to_bits(), "worker {w}: clip fraction diverges");
    }
    // and the dyn-reported value matches a single-process replay's
    let mut ref_opt = Helene::paper_defaults().with_lr(LR);
    let _ = helene::dist::replay_commit_log(&base_params(), &mut ref_opt, &report.log).unwrap();
    assert_eq!(first.to_bits(), Helene::clip_fraction(&ref_opt).to_bits());
    // a non-clipping optimizer stays None end-to-end
    let (_c, rep) = run_dist(dist_cfg(2, FaultPlan::new()));
    assert!(rep.clip_fractions.iter().all(Option::is_none));
}

/// Satellite: the bf16 θ-arena over the distributed tier. The pipelined
/// single-process protocol is bitwise-equal to the naive one in f32
/// only, so the tier — whose apply path IS the naive arithmetic — is
/// pinned against the **naive-config** reference here: same walk, same
/// fold, same update, in both the pairwise and multi-probe protocols,
/// across worker counts and under a death fault.
#[test]
fn bf16_dist_runs_match_the_naive_reference_across_worker_counts() {
    let naive = |q: usize| -> (Vec<f32>, ParamSet) {
        let base = base_params().with_codec(Codec::Bf16);
        let n_shards = base.n_shards();
        let mut oracle = SepQuadOracle::new();
        let cfg = TrainConfig {
            steps: STEPS,
            spsa_eps: EPS,
            seed: RUN_SEED,
            probes: q,
            cache_z: false,
            fuse_restore: false,
            prefetch_perturb: false,
            ..Default::default()
        };
        let mut opt = ZoSgd::new(LR);
        opt.init(&base);
        let mut params = base.clone();
        let mut proto = ZoProtocol::new(&cfg);
        let mut losses = Vec::with_capacity(STEPS);
        for step in 1..=STEPS {
            let step_seed = mix64(RUN_SEED, step as u64);
            let next_seed = mix64(RUN_SEED, step as u64 + 1);
            let loss_fn = |p: &ParamSet| {
                Ok(fold_partial_losses(oracle.shard_partials(p, 0..n_shards, step as u64)?))
            };
            let est_loss = if q > 1 {
                proto
                    .step_multi(&mut opt, &mut params, step_seed, next_seed, true, loss_fn)
                    .unwrap()
                    .loss()
            } else {
                proto
                    .step(&mut opt, &mut params, step_seed, next_seed, true, loss_fn)
                    .unwrap()
                    .loss()
            };
            losses.push(est_loss);
        }
        (losses, params)
    };
    for q in [1usize, 4] {
        let (ref_losses, ref_params) = naive(q);
        for workers in [1usize, 2, 4] {
            let tag = format!("bf16/q={q}/workers={workers}");
            let mut cfg = dist_cfg(workers, FaultPlan::new());
            cfg.probes = q;
            let mut coord = Coordinator::launch_threads(
                cfg,
                base_params().with_codec(Codec::Bf16),
                factory(),
            )
            .unwrap();
            let report = if q > 1 {
                coord.run_multi(STEPS, RUN_SEED).unwrap()
            } else {
                coord.run(STEPS, RUN_SEED).unwrap()
            };
            assert_bitwise(&tag, &report, &ref_losses, &ref_params);
            assert_eq!(report.params.codec(), Codec::Bf16, "{tag}: codec lost in transit");
            for (w, replica) in coord.fetch_all().unwrap() {
                assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
            }
        }
    }
}

#[test]
fn faulted_runs_stay_bitwise_identical_and_recover() {
    let (ref_losses, ref_params) = reference_run();
    // three distinct fault families: worker death mid-step, a dropped
    // reply plus a delayed (late, discarded) reply, a poisoned partial
    let plans = [
        ("death", "die@3:1"),
        ("drop+delay", "drop@2:0,delay@4:1:200"),
        ("nan-partial", "nan@2:1"),
    ];
    for (name, spec) in plans {
        let plan = FaultPlan::parse(spec).unwrap();
        for workers in [2usize, 4] {
            let tag = format!("{name}/workers={workers}");
            let (mut coord, report) = run_dist(dist_cfg(workers, plan.clone()));
            assert_bitwise(&tag, &report, &ref_losses, &ref_params);
            match name {
                "death" => {
                    assert!(report.stats.deaths >= 1, "{tag}: no death recorded");
                    assert!(report.stats.recoveries >= 1, "{tag}: no recovery recorded");
                    assert_eq!(report.workers_alive, workers, "{tag}: quorum not restored");
                }
                _ => {
                    assert!(report.stats.retries >= 1, "{tag}: fault never cost a retry");
                }
            }
            // every survivor (including any seed-log-replayed replacement)
            // holds the identical arena
            let replicas = coord.fetch_all().unwrap();
            for (w, replica) in &replicas {
                assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
            }
            // and a from-scratch replay of the committed log matches too
            let replayed =
                helene::dist::replay_commit_log(&base_params(), &mut ZoSgd::new(LR), &report.log)
                    .unwrap();
            assert!(replayed.bits_eq(&ref_params), "{tag}: replay diverges");
        }
    }
}

#[test]
fn recovery_off_degrades_to_the_surviving_quorum() {
    let (ref_losses, ref_params) = reference_run();
    let mut cfg = dist_cfg(3, FaultPlan::parse("die@2:2").unwrap());
    cfg.recover = false;
    let (_coord, report) = run_dist(cfg);
    assert_bitwise("degraded", &report, &ref_losses, &ref_params);
    assert_eq!(report.workers_alive, 2);
    assert_eq!(report.stats.deaths, 1);
    assert_eq!(report.stats.recoveries, 0);
}

#[test]
fn losing_every_worker_without_recovery_is_a_clear_error() {
    let mut cfg = dist_cfg(2, FaultPlan::parse("die@1:0,die@1:1").unwrap());
    cfg.recover = false;
    let mut coord = Coordinator::launch_threads(cfg, base_params(), factory()).unwrap();
    let err = format!("{:#}", coord.run(STEPS, RUN_SEED).unwrap_err());
    assert!(err.contains("no surviving workers"), "{err}");
}

/// An oracle that always fails: drives the retry loop to budget
/// exhaustion deterministically (injected faults fire only once, so they
/// can never exhaust the budget on their own).
struct AlwaysFailOracle;
impl ShardLossOracle for AlwaysFailOracle {
    fn shard_partials(
        &mut self,
        _params: &ParamSet,
        _shards: Range<usize>,
        _step: u64,
    ) -> anyhow::Result<Vec<f64>> {
        anyhow::bail!("synthetic oracle failure")
    }
}

#[test]
fn retry_budget_exhaustion_names_the_step_and_span() {
    let mut cfg = dist_cfg(1, FaultPlan::new());
    cfg.retry_budget = 2;
    let fail_factory: WorkerFactory = Box::new(|_slot| {
        Ok((
            Box::new(AlwaysFailOracle) as Box<dyn ShardLossOracle>,
            Box::new(ZoSgd::new(LR)) as Box<dyn Optimizer>,
        ))
    });
    let mut coord = Coordinator::launch_threads(cfg, base_params(), fail_factory).unwrap();
    let err = format!("{:#}", coord.run(STEPS, RUN_SEED).unwrap_err());
    assert!(err.contains("retry budget exhausted at step 1"), "{err}");
    assert!(err.contains("synthetic oracle failure"), "{err}");
}

#[test]
fn committed_records_persist_to_the_seed_log_file() {
    let dir = std::env::temp_dir().join("helene_dist_seedlog");
    let path = dir.join("run.sl");
    let _ = std::fs::remove_file(&path); // appends accumulate across runs
    let mut cfg = dist_cfg(2, FaultPlan::parse("die@3:1").unwrap());
    cfg.seed_log = Some(path.clone());
    let (_coord, report) = run_dist(cfg);
    // pairwise runs keep writing the v1 24-byte format …
    let on_disk = checkpoint::load_seed_log(&path).unwrap();
    let as_commits: Vec<CommitRecord> =
        on_disk.iter().map(|&r| CommitRecord::from(r)).collect();
    assert_eq!(as_commits, report.log);
    assert_eq!(on_disk.len(), STEPS);
    // … and the unified loader reads them back identically
    assert_eq!(checkpoint::load_commit_log(&path).unwrap(), report.log);
}

#[test]
fn multi_probe_records_persist_to_the_v2_commit_log() {
    let dir = std::env::temp_dir().join("helene_dist_commitlog");
    let path = dir.join("run.cl");
    let _ = std::fs::remove_file(&path);
    let mut cfg = dist_cfg(2, FaultPlan::parse("die@3:1").unwrap());
    cfg.probes = 4;
    cfg.seed_log = Some(path.clone());
    // `run()` delegates to the multi grid when probes > 1
    let (_coord, report) = run_dist(cfg);
    let on_disk = checkpoint::load_commit_log(&path).unwrap();
    assert_eq!(on_disk, report.log);
    assert_eq!(on_disk.len(), STEPS);
    assert!(on_disk.iter().all(|r| !r.pairwise && r.probes.len() == 4));
    // the persisted log alone rebuilds the final parameters
    let replayed =
        helene::dist::replay_commit_log(&base_params(), &mut ZoSgd::new(LR), &on_disk).unwrap();
    assert!(replayed.bits_eq(&report.params));
}

#[test]
fn dist_config_rejects_bad_knobs_with_actionable_messages() {
    let bad = [
        (DistConfig { workers: 0, ..Default::default() }, "workers must be >= 1"),
        (
            DistConfig { timeout: Duration::ZERO, ..Default::default() },
            "timeout must be > 0",
        ),
        (
            DistConfig { retry_budget: 0, ..Default::default() },
            "retry budget must be >= 1",
        ),
        (DistConfig { eps: f32::NAN, ..Default::default() }, "eps must be finite"),
        (DistConfig { probes: 0, ..Default::default() }, "probes must be >= 1"),
        (
            DistConfig { wave_backoff: Some(Duration::ZERO), ..Default::default() },
            "wave backoff must be > 0",
        ),
    ];
    for (cfg, needle) in bad {
        let err = format!("{:#}", cfg.validate().unwrap_err());
        assert!(err.contains(needle), "{err:?} should contain {needle:?}");
    }
}

/// Satellite: seed-log replay coverage across checkpoints and codecs.
/// Record a naive-config run's `(step, seed, g, eps)` log, checkpoint at
/// step k, keep training to k+m; truncating the log at step k and
/// replaying from the step-0 arena must land bitwise on the step-k
/// checkpoint — in both storage codecs. (The naive config is used because
/// its per-step arithmetic is exactly `probe_cycle` + `step_zo` in every
/// codec; the pipelined config is bitwise-equal to it in f32 only.)
#[test]
fn seed_log_replay_lands_on_the_checkpoint_in_both_codecs() {
    let (k, m) = (4usize, 3usize);
    for codec in [Codec::F32, Codec::Bf16] {
        let dir = std::env::temp_dir().join(format!("helene_replay_{}", codec.name()));
        let base = base_params().with_codec(codec);
        let n_shards = base.n_shards();
        let mut oracle = SepQuadOracle::new();
        let cfg = TrainConfig {
            steps: k + m,
            spsa_eps: EPS,
            seed: RUN_SEED,
            cache_z: false,
            fuse_restore: false,
            prefetch_perturb: false,
            ..Default::default()
        };
        let mut opt = ZoSgd::new(LR);
        opt.init(&base);
        let mut params = base.clone();
        let mut proto = ZoProtocol::new(&cfg);
        let mut records = Vec::new();
        let ckpt = dir.join("step_k.bin");
        for step in 1..=k + m {
            let step_seed = mix64(RUN_SEED, step as u64);
            let next_seed = mix64(RUN_SEED, step as u64 + 1);
            let est = proto
                .step(&mut opt, &mut params, step_seed, next_seed, true, |p| {
                    Ok(fold_partial_losses(
                        oracle.shard_partials(p, 0..n_shards, step as u64)?,
                    ))
                })
                .unwrap();
            records.push(SeedRecord {
                step: step as u64,
                seed: est.seed,
                g: est.g_scale,
                eps: EPS,
            });
            if step == k {
                // the naive protocol leaves θ pristine after every step
                checkpoint::save(&ckpt, k, &params, &[]).unwrap();
            }
        }
        proto.finish(&mut params);

        // persist the full log, reload it, truncate at step k, replay
        let log_path = dir.join("run.sl");
        checkpoint::write_seed_log(&log_path, &records).unwrap();
        let loaded = checkpoint::load_seed_log(&log_path).unwrap();
        assert_eq!(loaded, records);
        let replayed = helene::dist::replay_seed_log(
            &base,
            &mut ZoSgd::new(LR),
            &loaded[..k],
        )
        .unwrap();
        let (step, at_k, _) = checkpoint::load(&ckpt, base.spec.clone()).unwrap();
        assert_eq!(step, k);
        assert_eq!(replayed.codec(), at_k.codec());
        assert!(
            replayed.bits_eq(&at_k),
            "{}: replay of the first {k} records does not land on the step-{k} checkpoint",
            codec.name()
        );
    }
}

/// The single-process adapted-ε reference: `ZoProtocol::new_adapted`
/// over the same oracle with the default (pipelined) config. Returns the
/// loss trace, the final arena, and the per-step ε trace — the ε each
/// step's probes actually used, which is exactly what the coordinator
/// commits in its records.
fn reference_run_adapted(q: usize) -> (Vec<f32>, ParamSet, Vec<f32>) {
    let base = base_params();
    let n_shards = base.n_shards();
    let mut oracle = SepQuadOracle::new();
    let cfg = TrainConfig {
        steps: STEPS,
        spsa_eps: EPS,
        seed: RUN_SEED,
        probes: q,
        adapt_eps: Some(EpsAdaptConfig::default()),
        ..Default::default()
    };
    let mut opt = ZoSgd::new(LR);
    opt.init(&base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new_adapted(&cfg, bf16_eps_floor(&base)).unwrap();
    let mut losses = Vec::with_capacity(STEPS);
    let mut eps_trace = Vec::with_capacity(STEPS);
    for step in 1..=STEPS {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        let boundary = step == STEPS;
        eps_trace.push(proto.eps());
        let est = proto
            .step_multi(&mut opt, &mut params, step_seed, next_seed, boundary, |p| {
                Ok(fold_partial_losses(
                    oracle.shard_partials(p, 0..n_shards, step as u64)?,
                ))
            })
            .unwrap();
        losses.push(est.loss());
    }
    (losses, params, eps_trace)
}

#[test]
fn adapted_eps_runs_match_the_single_process_reference_and_replay() {
    // the tentpole invariant under ε adaptation: the coordinator folds
    // the same raw probe scalars into an identically-constructed
    // schedule at the same point in the step as the single-process
    // protocol, so the committed ε trace, the loss trace, and the final
    // arena are all bitwise — healthy or faulted, for any worker count,
    // and through replacement-by-replay (every commit record carries the
    // ε its probes used, so the schedule never has to be re-run)
    let plans = [("healthy", ""), ("death", "die@3:1"), ("nan", "nan@2:1")];
    for q in [1usize, 4] {
        let (ref_losses, ref_params, ref_eps) = reference_run_adapted(q);
        // adaptation must actually move ε (annealing alone shrinks it);
        // a constant trace would make this test vacuous
        assert!(
            ref_eps.windows(2).any(|w| w[0].to_bits() != w[1].to_bits()),
            "q={q}: the adapted ε trace never moved"
        );
        for (name, spec) in plans {
            for workers in [1usize, 2, 4] {
                if !spec.is_empty() && workers < 2 {
                    continue; // the fault plans key on worker 1
                }
                let tag = format!("adapt/{name}/q={q}/workers={workers}");
                let plan = if spec.is_empty() {
                    FaultPlan::new()
                } else {
                    FaultPlan::parse(spec).unwrap()
                };
                let mut cfg = dist_cfg(workers, plan);
                cfg.probes = q;
                cfg.adapt = Some(EpsAdaptConfig::default());
                // drive through `run()`: it must route to the multi grid
                // whenever adaptation is on — even at q = 1
                let mut coord =
                    Coordinator::launch_threads(cfg, base_params(), factory()).unwrap();
                let report = coord.run(STEPS, RUN_SEED).unwrap();
                assert_bitwise(&tag, &report, &ref_losses, &ref_params);
                assert_eq!(report.log.len(), STEPS, "{tag}: record count");
                for (i, rec) in report.log.iter().enumerate() {
                    assert_eq!(
                        rec.eps.to_bits(),
                        ref_eps[i].to_bits(),
                        "{tag}: committed ε diverges at step {} ({} vs {})",
                        i + 1,
                        rec.eps,
                        ref_eps[i]
                    );
                }
                for (w, replica) in coord.fetch_all().unwrap() {
                    assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
                }
                let replayed = helene::dist::replay_commit_log(
                    &base_params(),
                    &mut ZoSgd::new(LR),
                    &report.log,
                )
                .unwrap();
                assert!(replayed.bits_eq(&ref_params), "{tag}: replay diverges");
            }
        }
    }
}

/// Satellite: the adapted-ε commit log is self-contained. Record a
/// naive-config adapted run (its per-step arithmetic is exactly
/// `multi_probe_cycle` + `step_zo_multi` in every codec), checkpoint at
/// step k, keep training to k + m; truncating the v2 log at step k and
/// replaying from the step-0 arena must land bitwise on the step-k
/// checkpoint — in both storage codecs, without ever consulting the
/// schedule (each record's ε is the one its probes used).
#[test]
fn adapted_commit_log_truncates_and_replays_onto_checkpoints_in_both_codecs() {
    let (k, m, q) = (4usize, 3usize, 4usize);
    // ε₀ above the bf16 floor (mean|θ|/256 ≈ 1.95e-3 for this arena) so
    // the bf16 run anneals freely instead of pinning to the floor
    let eps0 = 5e-3f32;
    for codec in [Codec::F32, Codec::Bf16] {
        let dir = std::env::temp_dir().join(format!("helene_adapt_replay_{}", codec.name()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = base_params().with_codec(codec);
        let n_shards = base.n_shards();
        let mut oracle = SepQuadOracle::new();
        let cfg = TrainConfig {
            steps: k + m,
            spsa_eps: eps0,
            seed: RUN_SEED,
            probes: q,
            adapt_eps: Some(EpsAdaptConfig::default()),
            cache_z: false,
            fuse_restore: false,
            prefetch_perturb: false,
            ..Default::default()
        };
        let mut opt = ZoSgd::new(LR);
        opt.init(&base);
        let mut params = base.clone();
        let mut proto = ZoProtocol::new_adapted(&cfg, bf16_eps_floor(&base)).unwrap();
        let mut records = Vec::new();
        let ckpt = dir.join("step_k.bin");
        for step in 1..=k + m {
            let step_seed = mix64(RUN_SEED, step as u64);
            let next_seed = mix64(RUN_SEED, step as u64 + 1);
            // the ε this step's probes use — what the coordinator commits
            let eps_step = proto.eps();
            let est = proto
                .step_multi(&mut opt, &mut params, step_seed, next_seed, true, |p| {
                    Ok(fold_partial_losses(
                        oracle.shard_partials(p, 0..n_shards, step as u64)?,
                    ))
                })
                .unwrap();
            records.push(CommitRecord::multi(step as u64, eps_step, est.probes.clone()));
            if step == k {
                // the naive protocol leaves θ pristine after every step
                checkpoint::save(&ckpt, k, &params, &[]).unwrap();
            }
        }
        // ε must have actually moved, or this collapses to the fixed test
        assert!(
            records.windows(2).any(|w| w[0].eps.to_bits() != w[1].eps.to_bits()),
            "{}: the adapted ε trace never moved",
            codec.name()
        );
        // the full log round-trips through disk …
        let log_path = dir.join("run.cl");
        checkpoint::write_commit_log(&log_path, &records).unwrap();
        let loaded = checkpoint::load_commit_log(&log_path).unwrap();
        assert_eq!(loaded, records);
        // … and the step-k prefix alone rebuilds the step-k checkpoint
        let replayed =
            helene::dist::replay_commit_log(&base, &mut ZoSgd::new(LR), &loaded[..k]).unwrap();
        let (step, at_k, _) = checkpoint::load(&ckpt, base.spec.clone()).unwrap();
        assert_eq!(step, k);
        assert_eq!(replayed.codec(), at_k.codec());
        assert!(
            replayed.bits_eq(&at_k),
            "{}: adapted replay of the first {k} records does not land on the \
             step-{k} checkpoint",
            codec.name()
        );
    }
}
