//! L1 ↔ L3 agreement: the compiled fused Pallas optimizer kernels
//! (`fused_update.N.hlo.txt`, `agnb_ema.N.hlo.txt`) compute exactly what the
//! Rust host-side HELENE update computes.

use helene::runtime::{lit_f32, Runtime};
use helene::util::rng::Pcg64;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime loads"))
}

fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_normal(&mut v);
    v
}

/// Host-side mirror of the fused update (same math as Helene::apply's
/// inner kernel and kernels/ref.py).
#[allow(clippy::too_many_arguments)]
fn host_update(
    theta: &[f32], m: &[f32], h: &[f32], z: &[f32],
    sc: &[f32; 8],
) -> (Vec<f32>, Vec<f32>) {
    let [g_scale, alpha, beta1, lr, gamma, lam, eps, wd] = *sc;
    let mut t_out = theta.to_vec();
    let mut m_out = m.to_vec();
    for j in 0..theta.len() {
        let g = g_scale * z[j];
        m_out[j] = beta1 * m[j] + alpha * g;
        let denom = gamma * h[j].max(lam) + eps;
        t_out[j] = theta[j] - lr * wd * theta[j] - lr * m_out[j] / denom;
    }
    (t_out, m_out)
}

#[test]
fn fused_update_artifact_matches_host_math() {
    let Some(rt) = runtime() else { return };
    let Some(fk) = rt.manifest.fused.first().cloned() else {
        panic!("manifest has no fused kernels");
    };
    let n = fk.n;
    let mut rng = Pcg64::new(99);
    let theta = randv(&mut rng, n);
    let m = randv(&mut rng, n);
    let h: Vec<f32> = randv(&mut rng, n).iter().map(|x| x.abs()).collect();
    let z = randv(&mut rng, n);
    let sc = [0.7f32, 0.93, 0.9, 1e-3, 1.0, 1.0, 1e-8, 0.01];

    let args = vec![
        lit_f32(&theta, &[n]).unwrap(),
        lit_f32(&m, &[n]).unwrap(),
        lit_f32(&h, &[n]).unwrap(),
        lit_f32(&z, &[n]).unwrap(),
        lit_f32(&sc, &[1, 8]).unwrap(),
    ];
    let out = rt.execute(&fk.update_file, &args).unwrap();
    assert_eq!(out.len(), 2);
    let t_dev = out[0].to_vec::<f32>().unwrap();
    let m_dev = out[1].to_vec::<f32>().unwrap();

    let (t_host, m_host) = host_update(&theta, &m, &h, &z, &sc);
    for j in 0..n {
        assert!(
            (t_dev[j] - t_host[j]).abs() < 1e-5 * t_host[j].abs().max(1.0),
            "theta[{j}]: dev {} vs host {}",
            t_dev[j],
            t_host[j]
        );
        assert!((m_dev[j] - m_host[j]).abs() < 1e-5 * m_host[j].abs().max(1.0));
    }
}

#[test]
fn agnb_ema_artifact_matches_host_math() {
    let Some(rt) = runtime() else { return };
    let fk = rt.manifest.fused.first().cloned().unwrap();
    let n = fk.n;
    let mut rng = Pcg64::new(7);
    let h: Vec<f32> = randv(&mut rng, n).iter().map(|x| x.abs()).collect();
    let z = randv(&mut rng, n);
    let sc = [0.4f32, 8.0, 0.99];

    let args = vec![
        lit_f32(&h, &[n]).unwrap(),
        lit_f32(&z, &[n]).unwrap(),
        lit_f32(&sc, &[1, 3]).unwrap(),
    ];
    let out = rt.execute(&fk.ema_file, &args).unwrap();
    let h_dev = out[0].to_vec::<f32>().unwrap();
    for j in 0..n {
        let g = sc[0] * z[j];
        let want = sc[2] * h[j] + (1.0 - sc[2]) * sc[1] * g * g;
        assert!(
            (h_dev[j] - want).abs() < 1e-5 * want.abs().max(1.0),
            "h[{j}]: {} vs {want}",
            h_dev[j]
        );
    }
}

#[test]
fn fused_kernel_roundtrip_is_stable_across_calls() {
    // applying the kernel twice from the same inputs gives identical
    // results (no hidden state in the executable)
    let Some(rt) = runtime() else { return };
    let fk = rt.manifest.fused.first().cloned().unwrap();
    let n = fk.n;
    let mut rng = Pcg64::new(5);
    let theta = randv(&mut rng, n);
    let zero = vec![0f32; n];
    let sc = [1.0f32, 1.0, 0.0, 1e-2, 1.0, 0.5, 0.0, 0.0];
    let args = || {
        vec![
            lit_f32(&theta, &[n]).unwrap(),
            lit_f32(&zero, &[n]).unwrap(),
            lit_f32(&zero, &[n]).unwrap(),
            lit_f32(&theta, &[n]).unwrap(),
            lit_f32(&sc, &[1, 8]).unwrap(),
        ]
    };
    let a = rt.execute(&fk.update_file, &args()).unwrap()[0].to_vec::<f32>().unwrap();
    let b = rt.execute(&fk.update_file, &args()).unwrap()[0].to_vec::<f32>().unwrap();
    assert_eq!(a, b);
}
