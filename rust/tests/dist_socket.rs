//! Property suite for the socket-backed distributed tier
//! (`helene::dist::socket`): the PR 7 bitwise matrix re-run over real
//! loopback TCP — checksummed frames, connect handshake, timeouts,
//! redials — plus the wire-level fault families (`cut` / `corrupt` /
//! `stall`) injected by the in-path [`FaultProxy`]. Every faulted run
//! must end **bitwise identical** (f32 arenas) to the unfaulted
//! single-worker `ZoProtocol`, including runs where a worker's
//! connection is severed mid-step and it recovers by redialing and
//! replaying the handshake's commit log (reconnect-by-replay). The
//! multi-probe grid (`probes` > 1) rides the same wire matrix, and the
//! handshake's config fingerprint refuses mismatched workers by name.

use std::sync::mpsc;
use std::time::Duration;

use helene::dist::{
    param_digest, run_socket_worker, ConfigFingerprint, Coordinator, DistConfig,
    DistReport, FaultPlan, FaultProxy, SepQuadOracle, ShardLossOracle, SocketConfig,
    SocketEndpoint, SocketTransport, Worker, WorkerExit, WorkerFactory,
};
use helene::model::params::{ParamSet, SHARD_SIZE};
use helene::optim::spsa::{bf16_eps_floor, fold_partial_losses, EpsAdaptConfig};
use helene::optim::zo_sgd::ZoSgd;
use helene::optim::Optimizer;
use helene::train::{TrainConfig, ZoProtocol};
use helene::util::rng::mix64;

const STEPS: usize = 6;
const RUN_SEED: u64 = 11;
const EPS: f32 = 1e-3;
const LR: f32 = 0.01;

fn base_params() -> ParamSet {
    // same arena as tests/dist_fault.rs: 5 shards over two layer groups,
    // so every worker count dispatches real disjoint spans
    ParamSet::synthetic(&[3 * SHARD_SIZE, 2 * SHARD_SIZE], 0.5)
}

fn factory() -> WorkerFactory {
    Box::new(|_slot| {
        Ok((
            Box::new(SepQuadOracle::new()) as Box<dyn ShardLossOracle>,
            Box::new(ZoSgd::new(LR)) as Box<dyn Optimizer>,
        ))
    })
}

fn dist_cfg(workers: usize, plan: FaultPlan) -> DistConfig {
    DistConfig {
        workers,
        eps: EPS,
        timeout: Duration::from_millis(40),
        retry_budget: 3,
        recover: true,
        fault_plan: plan,
        seed_log: None,
        probes: 1,
        wave_backoff: None,
        adapt: None,
    }
}

/// Socket knobs tuned for the test box: quick read polls, a short
/// mid-frame stall budget (the `stall` fault must overrun it), fast
/// redials with a budget that rides out a whole run of disconnects.
fn test_scfg() -> SocketConfig {
    SocketConfig {
        read_timeout: Duration::from_millis(10),
        stall_timeout: Duration::from_millis(150),
        redial_attempts: 500,
        redial_backoff: Duration::from_millis(10),
        await_live_timeout: Duration::from_secs(10),
        ..Default::default()
    }
}

/// The unfaulted single-worker reference (identical to dist_fault.rs).
fn reference_run() -> (Vec<f32>, ParamSet) {
    let base = base_params();
    let n_shards = base.n_shards();
    let mut oracle = SepQuadOracle::new();
    let cfg = TrainConfig { steps: STEPS, spsa_eps: EPS, seed: RUN_SEED, ..Default::default() };
    let mut opt = ZoSgd::new(LR);
    opt.init(&base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new(&cfg);
    let mut losses = Vec::with_capacity(STEPS);
    for step in 1..=STEPS {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        let boundary = step == STEPS;
        let est = proto
            .step(&mut opt, &mut params, step_seed, next_seed, boundary, |p| {
                Ok(fold_partial_losses(
                    oracle.shard_partials(p, 0..n_shards, step as u64)?,
                ))
            })
            .unwrap();
        losses.push(est.loss());
    }
    proto.finish(&mut params);
    (losses, params)
}

/// The single-process multi-probe reference (identical to
/// dist_fault.rs): pipelined `step_multi` with the last step run as a
/// boundary, which aligns the cumulative per-element op sequence with
/// the tier's apply path.
fn reference_run_multi(q: usize) -> (Vec<f32>, ParamSet) {
    let base = base_params();
    let n_shards = base.n_shards();
    let mut oracle = SepQuadOracle::new();
    let cfg = TrainConfig {
        steps: STEPS,
        spsa_eps: EPS,
        seed: RUN_SEED,
        probes: q,
        ..Default::default()
    };
    let mut opt = ZoSgd::new(LR);
    opt.init(&base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new(&cfg);
    let mut losses = Vec::with_capacity(STEPS);
    for step in 1..=STEPS {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        let boundary = step == STEPS;
        let est = proto
            .step_multi(&mut opt, &mut params, step_seed, next_seed, boundary, |p| {
                Ok(fold_partial_losses(
                    oracle.shard_partials(p, 0..n_shards, step as u64)?,
                ))
            })
            .unwrap();
        losses.push(est.loss());
    }
    (losses, params)
}

/// The single-process adapted-ε reference (identical to dist_fault.rs):
/// pipelined `step_multi` through `ZoProtocol::new_adapted`, recording
/// the ε each step's probes used alongside the loss trace.
fn reference_run_adapted(q: usize) -> (Vec<f32>, ParamSet, Vec<f32>) {
    let base = base_params();
    let n_shards = base.n_shards();
    let mut oracle = SepQuadOracle::new();
    let cfg = TrainConfig {
        steps: STEPS,
        spsa_eps: EPS,
        seed: RUN_SEED,
        probes: q,
        adapt_eps: Some(EpsAdaptConfig::default()),
        ..Default::default()
    };
    let mut opt = ZoSgd::new(LR);
    opt.init(&base);
    let mut params = base.clone();
    let mut proto = ZoProtocol::new_adapted(&cfg, bf16_eps_floor(&base)).unwrap();
    let mut losses = Vec::with_capacity(STEPS);
    let mut eps_trace = Vec::with_capacity(STEPS);
    for step in 1..=STEPS {
        let step_seed = mix64(RUN_SEED, step as u64);
        let next_seed = mix64(RUN_SEED, step as u64 + 1);
        let boundary = step == STEPS;
        eps_trace.push(proto.eps());
        let est = proto
            .step_multi(&mut opt, &mut params, step_seed, next_seed, boundary, |p| {
                Ok(fold_partial_losses(
                    oracle.shard_partials(p, 0..n_shards, step as u64)?,
                ))
            })
            .unwrap();
        losses.push(est.loss());
    }
    (losses, params, eps_trace)
}

/// Run the tier over loopback TCP with in-process dialer threads.
fn run_socket(cfg: DistConfig) -> (Coordinator<SocketTransport>, DistReport) {
    let mut coord = Coordinator::launch_socket_threads(
        cfg,
        base_params(),
        factory(),
        RUN_SEED,
        test_scfg(),
        None,
    )
    .unwrap();
    let report = coord.run(STEPS, RUN_SEED).unwrap();
    (coord, report)
}

/// Like [`run_socket`] but drives the multi-probe grid directly, so the
/// q = 1 multi semantics are reachable too.
fn run_socket_multi(cfg: DistConfig) -> (Coordinator<SocketTransport>, DistReport) {
    let mut coord = Coordinator::launch_socket_threads(
        cfg,
        base_params(),
        factory(),
        RUN_SEED,
        test_scfg(),
        None,
    )
    .unwrap();
    let report = coord.run_multi(STEPS, RUN_SEED).unwrap();
    (coord, report)
}

/// Run the tier with a [`FaultProxy`] in path: workers dial the proxy,
/// the proxy dials the coordinator and injects the plan's wire-class
/// faults on the worker→coordinator direction.
fn run_via_proxy(cfg: DistConfig) -> (Coordinator<SocketTransport>, FaultProxy, DistReport) {
    let base = base_params();
    let mut scfg = test_scfg();
    scfg.restart_on_fault = cfg.recover;
    let mut transport = SocketTransport::listen(
        "127.0.0.1:0",
        cfg.workers,
        RUN_SEED,
        param_digest(&base),
        scfg,
    )
    .unwrap();
    let proxy = FaultProxy::start(transport.local_addr(), cfg.fault_plan.clone()).unwrap();
    transport.set_dial_addr(proxy.addr());
    let worker_base = base.clone();
    let mut spawned = vec![false; cfg.workers];
    let spawner: Box<dyn FnMut(usize, Worker, SocketEndpoint) -> anyhow::Result<()>> =
        Box::new(move |slot, worker, ep| {
            if spawned[slot] {
                return Ok(()); // the dialer thread self-redials
            }
            spawned[slot] = true;
            let b = worker_base.clone();
            std::thread::Builder::new()
                .name(format!("test-sock-worker-{slot}"))
                .spawn(move || {
                    let _ = run_socket_worker(worker, b, ep);
                })
                .map(|_| ())
                .map_err(anyhow::Error::from)
        });
    let mut coord = Coordinator::new(cfg, base, factory(), transport, spawner).unwrap();
    let report = coord.run(STEPS, RUN_SEED).unwrap();
    (coord, proxy, report)
}

fn assert_bitwise(tag: &str, report: &DistReport, ref_losses: &[f32], ref_params: &ParamSet) {
    assert_eq!(report.losses.len(), ref_losses.len(), "{tag}: step count");
    for (i, (a, b)) in report.losses.iter().zip(ref_losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{tag}: loss trace diverges at step {} ({a} vs {b})",
            i + 1
        );
    }
    assert!(report.params.bits_eq(ref_params), "{tag}: final params diverge");
}

#[test]
fn unfaulted_socket_runs_match_the_single_worker_protocol() {
    let (ref_losses, ref_params) = reference_run();
    for workers in [1usize, 2, 4] {
        let (mut coord, report) = run_socket(dist_cfg(workers, FaultPlan::new()));
        assert_bitwise(&format!("socket/workers={workers}"), &report, &ref_losses, &ref_params);
        assert_eq!(report.workers_alive, workers);
        assert_eq!(report.stats.deaths, 0);
        assert_eq!(report.stats.wire_reconnects, 0, "healthy lanes must not redial");
        for (w, replica) in coord.fetch_all().unwrap() {
            assert!(replica.bits_eq(&ref_params), "workers={workers}: replica {w} diverges");
        }
        let replayed =
            helene::dist::replay_commit_log(&base_params(), &mut ZoSgd::new(LR), &report.log)
                .unwrap();
        assert!(replayed.bits_eq(&ref_params), "workers={workers}: replay diverges");
    }
}

#[test]
fn multi_probe_socket_runs_match_the_single_process_step_multi() {
    // the probe grid over real TCP: every (point, span) item travels as
    // a checksummed ProbePoint frame, the multi-record commit as an
    // ApplyMulti broadcast — still bitwise the single-process pipeline
    for q in [1usize, 4] {
        let (ref_losses, ref_params) = reference_run_multi(q);
        for workers in [1usize, 2, 4] {
            let tag = format!("socket/q={q}/workers={workers}");
            let mut cfg = dist_cfg(workers, FaultPlan::new());
            cfg.probes = q;
            let (mut coord, report) = run_socket_multi(cfg);
            assert_bitwise(&tag, &report, &ref_losses, &ref_params);
            assert_eq!(report.stats.wire_reconnects, 0, "{tag}: healthy lanes redialed");
            assert!(report.log.iter().all(|r| !r.pairwise && r.probes.len() == q));
            for (w, replica) in coord.fetch_all().unwrap() {
                assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
            }
        }
    }
}

#[test]
fn adapted_eps_socket_runs_match_the_reference_and_survive_wire_faults() {
    // ε adaptation over real TCP: the schedule lives only in the
    // coordinator, the per-request ε rides every ProbePoint frame, and
    // each committed record carries the ε its probes used — so healthy
    // lanes at any worker count land bitwise on the single-process
    // adapted reference, losses, ε trace, and arenas alike
    let q = 4usize;
    let (ref_losses, ref_params, ref_eps) = reference_run_adapted(q);
    for workers in [1usize, 2, 4] {
        let tag = format!("socket/adapt/workers={workers}");
        let mut cfg = dist_cfg(workers, FaultPlan::new());
        cfg.probes = q;
        cfg.adapt = Some(EpsAdaptConfig::default());
        // `run()` must route to the multi grid whenever adaptation is on
        let (mut coord, report) = run_socket(cfg);
        assert_bitwise(&tag, &report, &ref_losses, &ref_params);
        for (i, rec) in report.log.iter().enumerate() {
            assert_eq!(
                rec.eps.to_bits(),
                ref_eps[i].to_bits(),
                "{tag}: committed ε diverges at step {}",
                i + 1
            );
        }
        for (w, replica) in coord.fetch_all().unwrap() {
            assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
        }
    }
    // a severed lane mid-run: the redial handshake replays the commit
    // log — whose records carry the adapted per-step ε — and the rebuilt
    // worker still lands bitwise
    let mut cfg = dist_cfg(2, FaultPlan::parse("cut@3:1").unwrap());
    cfg.probes = q;
    cfg.adapt = Some(EpsAdaptConfig::default());
    let (_coord, _proxy, report) = run_via_proxy(cfg);
    assert_bitwise("socket/adapt/cut", &report, &ref_losses, &ref_params);
    assert!(report.stats.wire_reconnects >= 1, "the cut never forced a redial");
    for (i, rec) in report.log.iter().enumerate() {
        assert_eq!(
            rec.eps.to_bits(),
            ref_eps[i].to_bits(),
            "socket/adapt/cut: committed ε diverges at step {}",
            i + 1
        );
    }
}

#[test]
fn worker_faults_over_sockets_stay_bitwise_identical() {
    let (ref_losses, ref_params) = reference_run();
    let plans = [
        ("death", "die@3:1"),
        ("drop+delay", "drop@2:0,delay@4:1:200"),
        ("nan-partial", "nan@2:1"),
    ];
    for (name, spec) in plans {
        let plan = FaultPlan::parse(spec).unwrap();
        for workers in [2usize, 4] {
            let tag = format!("socket/{name}/workers={workers}");
            let (mut coord, report) = run_socket(dist_cfg(workers, plan.clone()));
            assert_bitwise(&tag, &report, &ref_losses, &ref_params);
            match name {
                "death" => {
                    // over sockets the dialer loop is the supervisor: a
                    // dead incarnation redials in place, so the event
                    // shows up as a coordinator-observed death, a wire
                    // reconnect, or both — depending on whether the
                    // coordinator touched the lane in the gap
                    assert!(
                        report.stats.deaths >= 1 || report.stats.wire_reconnects >= 1,
                        "{tag}: the death left no trace in the stats"
                    );
                    assert_eq!(report.workers_alive, workers, "{tag}: quorum not restored");
                }
                _ => {
                    assert!(report.stats.retries >= 1, "{tag}: fault never cost a retry");
                }
            }
            for (w, replica) in coord.fetch_all().unwrap() {
                assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
            }
        }
    }
}

#[test]
fn wire_faults_stay_bitwise_identical_and_reconnect_by_replay() {
    let (ref_losses, ref_params) = reference_run();
    // one fault per wire family; the stall (400 ms) overruns the 150 ms
    // mid-frame budget, so the coordinator kills the lane and the worker
    // redials — every family must end in at least one reconnect
    let plans = [
        ("cut", "cut@3:1"),
        ("corrupt", "corrupt@2:0"),
        ("stall", "stall@4:1:400"),
    ];
    for (name, spec) in plans {
        let plan = FaultPlan::parse(spec).unwrap();
        for workers in [2usize, 4] {
            let tag = format!("wire/{name}/workers={workers}");
            let (mut coord, _proxy, report) = run_via_proxy(dist_cfg(workers, plan.clone()));
            assert_bitwise(&tag, &report, &ref_losses, &ref_params);
            assert!(
                report.stats.wire_reconnects >= 1,
                "{tag}: the wire fault never forced a reconnect"
            );
            assert_eq!(report.workers_alive, workers, "{tag}: quorum not restored");
            // the reconnected worker rebuilt from the handshake's seed
            // log — every replica, including it, must hold the exact
            // reference arena
            for (w, replica) in coord.fetch_all().unwrap() {
                assert!(replica.bits_eq(&ref_params), "{tag}: replica {w} diverges");
            }
        }
    }
}

#[test]
fn a_cut_mid_run_recovers_purely_from_the_handshake_seed_log() {
    // the focused reconnect-by-replay property: sever worker 1's lane at
    // step 3 of 6 — it must redial, rebuild bitwise from its retained
    // step-0 arena plus the acked records (steps committed while it was
    // gone included), and finish indistinguishable from a survivor
    let (ref_losses, ref_params) = reference_run();
    let (mut coord, _proxy, report) =
        run_via_proxy(dist_cfg(2, FaultPlan::parse("cut@3:1").unwrap()));
    assert_bitwise("reconnect-by-replay", &report, &ref_losses, &ref_params);
    assert!(report.stats.wire_reconnects >= 1, "no reconnect recorded");
    let replicas = coord.fetch_all().unwrap();
    assert_eq!(replicas.len(), 2, "both workers must survive the cut");
    for (w, replica) in &replicas {
        assert!(replica.bits_eq(&ref_params), "replica {w} diverges after replay");
    }
    // the committed log itself still replays to the reference arena
    let replayed =
        helene::dist::replay_commit_log(&base_params(), &mut ZoSgd::new(LR), &report.log).unwrap();
    assert!(replayed.bits_eq(&ref_params), "seed-log replay diverges");
}

#[test]
fn a_cut_mid_multi_probe_run_recovers_by_replaying_v2_records() {
    // reconnect-by-replay over the multi-probe grid: the redialing
    // worker's handshake ack carries v2 multi-commit records, and the
    // rebuild must walk each one through step_zo_multi to stay bitwise
    let q = 4usize;
    let (ref_losses, ref_params) = reference_run_multi(q);
    let mut cfg = dist_cfg(2, FaultPlan::parse("cut@3:1").unwrap());
    cfg.probes = q;
    let (mut coord, _proxy, report) = run_via_proxy(cfg);
    assert_bitwise("multi/reconnect-by-replay", &report, &ref_losses, &ref_params);
    assert!(report.stats.wire_reconnects >= 1, "the cut never forced a reconnect");
    assert!(
        report.log.iter().all(|r| !r.pairwise && r.probes.len() == q),
        "expected v2 multi records in the commit log"
    );
    for (w, replica) in coord.fetch_all().unwrap() {
        assert!(replica.bits_eq(&ref_params), "replica {w} diverges after replay");
    }
    let replayed =
        helene::dist::replay_commit_log(&base_params(), &mut ZoSgd::new(LR), &report.log).unwrap();
    assert!(replayed.bits_eq(&ref_params), "multi commit-log replay diverges");
}

#[test]
fn recovery_off_degrades_over_sockets_too() {
    let (ref_losses, ref_params) = reference_run();
    let mut cfg = dist_cfg(3, FaultPlan::parse("die@2:2").unwrap());
    cfg.recover = false; // also turns off the dialer's in-place restart
    let (_coord, report) = run_socket(cfg);
    assert_bitwise("socket/degraded", &report, &ref_losses, &ref_params);
    assert_eq!(report.workers_alive, 2);
    assert_eq!(report.stats.deaths, 1);
    assert_eq!(report.stats.recoveries, 0);
}

#[test]
fn shutdown_message_lets_every_worker_exit_cleanly() {
    // graceful-shutdown satellite: after the run, Coordinator::shutdown
    // broadcasts Request::Shutdown and each dialer loop must return
    // WorkerExit::Shutdown (the CLI's exit-code-0 path) rather than
    // treating the closing lane as a disconnect and redialing
    let workers = 2usize;
    let base = base_params();
    let transport = SocketTransport::listen(
        "127.0.0.1:0",
        workers,
        RUN_SEED,
        param_digest(&base),
        test_scfg(),
    )
    .unwrap();
    let (exit_tx, exit_rx) = mpsc::channel();
    let worker_base = base.clone();
    let spawner: Box<dyn FnMut(usize, Worker, SocketEndpoint) -> anyhow::Result<()>> =
        Box::new(move |_slot, worker, ep| {
            let b = worker_base.clone();
            let tx = exit_tx.clone();
            std::thread::spawn(move || {
                let _ = tx.send(run_socket_worker(worker, b, ep));
            });
            Ok(())
        });
    let mut coord =
        Coordinator::new(dist_cfg(workers, FaultPlan::new()), base, factory(), transport, spawner)
            .unwrap();
    let report = coord.run(STEPS, RUN_SEED).unwrap();
    assert_eq!(report.losses.len(), STEPS);
    coord.shutdown();
    for _ in 0..workers {
        let exit = exit_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("a worker never exited after shutdown")
            .expect("worker loop errored");
        assert_eq!(exit, WorkerExit::Shutdown, "worker did not see a clean shutdown");
    }
}

#[test]
fn handshake_refuses_a_mismatched_run_seed() {
    let base = base_params();
    let _transport = SocketTransport::listen(
        "127.0.0.1:0",
        1,
        RUN_SEED,
        param_digest(&base),
        test_scfg(),
    )
    .unwrap();
    let addr = _transport.local_addr();
    let worker = Worker::new(
        0,
        &base,
        Box::new(ZoSgd::new(LR)) as Box<dyn Optimizer>,
        Box::new(SepQuadOracle::new()) as Box<dyn ShardLossOracle>,
        FaultPlan::new(),
    );
    let ep = SocketEndpoint {
        addr,
        slot: 0,
        run_seed: RUN_SEED + 1, // wrong run seed
        base_digest: param_digest(&base),
        cfg: test_scfg(),
    };
    let err = format!("{:#}", run_socket_worker(worker, base, ep).unwrap_err());
    assert!(err.contains("refused"), "{err}");
    assert!(err.contains("run seed mismatch"), "{err}");
}

#[test]
fn handshake_refuses_a_mismatched_base_arena() {
    let base = base_params();
    let _transport = SocketTransport::listen(
        "127.0.0.1:0",
        1,
        RUN_SEED,
        param_digest(&base),
        test_scfg(),
    )
    .unwrap();
    let addr = _transport.local_addr();
    // a worker built from a *different* step-0 arena: same shape, other fill
    let other = ParamSet::synthetic(&[3 * SHARD_SIZE, 2 * SHARD_SIZE], 0.25);
    let worker = Worker::new(
        0,
        &other,
        Box::new(ZoSgd::new(LR)) as Box<dyn Optimizer>,
        Box::new(SepQuadOracle::new()) as Box<dyn ShardLossOracle>,
        FaultPlan::new(),
    );
    let ep = SocketEndpoint {
        addr,
        slot: 0,
        run_seed: RUN_SEED,
        base_digest: param_digest(&other),
        cfg: test_scfg(),
    };
    let err = format!("{:#}", run_socket_worker(worker, other, ep).unwrap_err());
    assert!(err.contains("arena mismatch"), "{err}");
}

#[test]
fn handshake_refuses_a_mismatched_config_fingerprint_naming_the_field() {
    // the silent-mismatch hole: a worker dialing with a different lr used
    // to pass the handshake and diverge bitwise mid-run. The refusal must
    // name the differing field — not hide behind a digest comparison.
    let base = base_params();
    let mut listen_scfg = test_scfg();
    listen_scfg.fingerprint = ConfigFingerprint {
        opt: "mezo".into(),
        lr: LR,
        eps: EPS,
        steps: STEPS as u64,
        probes: 4,
        adapt: None,
    };
    let _transport = SocketTransport::listen(
        "127.0.0.1:0",
        1,
        RUN_SEED,
        param_digest(&base),
        listen_scfg.clone(),
    )
    .unwrap();
    let addr = _transport.local_addr();
    let worker = Worker::new(
        0,
        &base,
        Box::new(ZoSgd::new(LR)) as Box<dyn Optimizer>,
        Box::new(SepQuadOracle::new()) as Box<dyn ShardLossOracle>,
        FaultPlan::new(),
    );
    let mut dial_scfg = listen_scfg;
    dial_scfg.fingerprint.lr = LR * 2.0; // worker launched with the wrong lr
    let ep = SocketEndpoint {
        addr,
        slot: 0,
        run_seed: RUN_SEED,
        base_digest: param_digest(&base),
        cfg: dial_scfg,
    };
    let err = format!("{:#}", run_socket_worker(worker, base, ep).unwrap_err());
    assert!(err.contains("refused"), "{err}");
    assert!(err.contains("lr mismatch: coordinator uses"), "{err}");
    assert!(
        !err.contains("digest") && !err.contains("arena mismatch"),
        "refusal must name the field, not a digest: {err}"
    );
}

#[test]
fn handshake_refuses_a_mismatched_eps_adaptation_naming_the_field() {
    // a worker dialed without --adapt-eps (or with different adaptation
    // hyperparameters) would replay the identical commit log yet expect a
    // different ε trajectory — it must be refused at connect, by name,
    // like every other fingerprint field
    use helene::optim::spsa::EpsAdaptConfig;
    let base = base_params();
    let mut listen_scfg = test_scfg();
    listen_scfg.fingerprint = ConfigFingerprint {
        opt: "mezo".into(),
        lr: LR,
        eps: EPS,
        steps: STEPS as u64,
        probes: 4,
        adapt: Some(EpsAdaptConfig::default()),
    };
    let _transport = SocketTransport::listen(
        "127.0.0.1:0",
        1,
        RUN_SEED,
        param_digest(&base),
        listen_scfg.clone(),
    )
    .unwrap();
    let addr = _transport.local_addr();
    for (dialed, want) in [
        (None, "eps-adaptation mismatch: coordinator runs adapt-eps = on"),
        (
            Some(EpsAdaptConfig { anneal: 0.5, ..Default::default() }),
            "adapt-anneal mismatch: coordinator uses",
        ),
    ] {
        let worker = Worker::new(
            0,
            &base,
            Box::new(ZoSgd::new(LR)) as Box<dyn Optimizer>,
            Box::new(SepQuadOracle::new()) as Box<dyn ShardLossOracle>,
            FaultPlan::new(),
        );
        let mut dial_scfg = listen_scfg.clone();
        dial_scfg.fingerprint.adapt = dialed;
        let ep = SocketEndpoint {
            addr,
            slot: 0,
            run_seed: RUN_SEED,
            base_digest: param_digest(&base),
            cfg: dial_scfg,
        };
        let err = format!("{:#}", run_socket_worker(worker, base.clone(), ep).unwrap_err());
        assert!(err.contains("refused"), "{err}");
        assert!(err.contains(want), "expected {want:?} in {err}");
    }
}
