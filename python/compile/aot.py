"""AOT lowering: JAX/Pallas → HLO text + manifest + initial parameters.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out ../artifacts

Emits, per (model × variant × entrypoint), an HLO **text** file — text, not
``.serialize()``: jax ≥ 0.5 writes HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Plus:

* ``manifest.json`` — the contract with the Rust coordinator: model configs,
  ordered parameter layouts (name/shape/layer-group/trainable/offset), and
  the entrypoint → file map with input/output descriptions.
* ``<model>.<variant>.params.bin`` — initial parameters, concatenated
  little-endian f32 in manifest order.
* standalone fused-optimizer kernels (``fused_update.N.hlo.txt``,
  ``agnb_ema.N.hlo.txt``) for the L1 ablation benches.

Python never runs after this step; the Rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.attention import mxu_flops, vmem_bytes
from compile.kernels.helene_update import (
    agnb_ema as agnb_ema_fn,
    hbm_traffic_bytes,
    helene_update as helene_update_fn,
)

# Which entrypoints to compile per model. The big LM only needs the training
# path (end-to-end example); the small models back the full experiment matrix.
FULL = ["loss", "logits", "loss_ref", "logits_ref", "loss_grad", "loss_jvp"]

MATRIX: dict[str, dict[str, list[str]]] = {
    "cls-tiny": {
        "ft": FULL,
        "lora": ["loss", "logits", "loss_ref", "logits_ref", "loss_grad"],
        "prefix": ["loss", "logits", "loss_ref", "logits_ref", "loss_grad"],
    },
    "cls-small": {"ft": FULL, "lora": FULL, "prefix": FULL},
    "dec-small": {"ft": FULL, "lora": FULL, "prefix": FULL},
    "lm-small": {"ft": ["loss", "logits", "loss_ref", "logits_ref", "loss_grad"]},
    "lm-big": {"ft": ["loss", "loss_ref", "loss_grad"]},
}

FUSED_SIZES = [16384, 65536]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True contract)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entrypoint(cfg: M.ModelConfig, variant: str, ep: str) -> str:
    fn, arg_specs = M.build_entrypoints(cfg, variant)[ep]
    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered)


def entry_io(cfg: M.ModelConfig, variant: str, ep: str) -> dict:
    """Describe the entrypoint's inputs/outputs for the manifest."""
    has_labels = cfg.kind != "lm"
    data = ["tokens"] + (["labels"] if has_labels else [])
    n = len(M.param_specs(cfg, variant))
    if ep in ("loss", "loss_ref"):
        return {"inputs": ["params"] + data, "outputs": ["loss"]}
    if ep in ("logits", "logits_ref"):
        return {"inputs": ["params", "tokens"], "outputs": ["logits"]}
    if ep == "loss_grad":
        return {"inputs": ["params"] + data, "outputs": ["loss"] + ["grads"] * n}
    if ep == "loss_jvp":
        return {"inputs": ["params", "tangents"] + data, "outputs": ["loss", "jvp"]}
    raise ValueError(ep)


def write_params_bin(path: str, params: list[jnp.ndarray]) -> int:
    total = 0
    with open(path, "wb") as f:
        for p in params:
            arr = np.asarray(p, dtype="<f4").ravel()
            f.write(arr.tobytes())
            total += arr.size
    return total


def lower_fused_kernels(out_dir: str) -> list[dict]:
    entries = []
    for n in FUSED_SIZES:
        vec = jax.ShapeDtypeStruct((n,), jnp.float32)
        sc8 = jax.ShapeDtypeStruct((1, 8), jnp.float32)
        sc3 = jax.ShapeDtypeStruct((1, 3), jnp.float32)

        def upd(theta, m, h, z, scal):
            return helene_update_fn(theta, m, h, z, scal)

        def ema(h, z, scal):
            return (agnb_ema_fn(h, z, scal),)

        f1 = f"fused_update.{n}.hlo.txt"
        with open(os.path.join(out_dir, f1), "w") as f:
            f.write(to_hlo_text(jax.jit(upd).lower(vec, vec, vec, vec, sc8)))
        f2 = f"agnb_ema.{n}.hlo.txt"
        with open(os.path.join(out_dir, f2), "w") as f:
            f.write(to_hlo_text(jax.jit(ema).lower(vec, vec, sc3)))
        entries.append(
            {
                "n": n,
                "update_file": f1,
                "update_scalars": ["g_scale", "alpha", "beta1", "lr", "gamma",
                                    "lam", "eps", "weight_decay"],
                "ema_file": f2,
                "ema_scalars": ["g_scale", "batch", "beta2"],
            }
        )
    return entries


def report(models: list[str]) -> None:
    """Print the VMEM/MXU accounting used by DESIGN.md §Perf."""
    print("== L1 kernel accounting (TPU estimates; executed interpret-mode) ==")
    for name in models:
        cfg = M.MODEL_ZOO[name]
        s, dh = cfg.max_seq, cfg.d_head
        bq = min(s, 128)
        vb = vmem_bytes(s, s, dh, bq)
        fl = mxu_flops(s, s, dh) * cfg.batch * cfg.n_heads * cfg.n_layers
        print(f"  {name}: attention tile VMEM={vb/1024:.1f} KiB, "
              f"MXU FLOPs/step(fwd)={fl/1e6:.2f} M")
    for n in FUSED_SIZES:
        fused = hbm_traffic_bytes(n, fused=True)
        unfused = hbm_traffic_bytes(n, fused=False)
        print(f"  fused_update n={n}: HBM {fused/1024:.0f} KiB vs unfused "
              f"{unfused/1024:.0f} KiB ({unfused/fused:.1f}x saved)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(MATRIX.keys()))
    ap.add_argument("--skip-big", action="store_true",
                    help="skip lm-big (fast test builds)")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()

    models = [m for m in args.models if not (args.skip_big and m == "lm-big")]
    if args.report:
        report(models)
        return

    os.makedirs(args.out, exist_ok=True)
    manifest: dict = {"format": 1, "models": [], "fused_kernels": []}

    for name in models:
        cfg = M.MODEL_ZOO[name]
        mrec: dict = {
            "name": name,
            "kind": cfg.kind,
            "config": {
                "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
                "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
                "n_classes": cfg.n_classes, "batch": cfg.batch,
                "lora_rank": cfg.lora_rank, "lora_alpha": cfg.lora_alpha,
                "prefix_len": cfg.prefix_len,
            },
            "variants": {},
        }
        for variant, eps in MATRIX[name].items():
            t0 = time.time()
            specs = M.param_specs(cfg, variant)
            params = M.init_params(cfg, variant, seed=0)
            bin_name = f"{name}.{variant}.params.bin"
            total = write_params_bin(os.path.join(args.out, bin_name), params)

            offset = 0
            prec = []
            for s in specs:
                prec.append({
                    "name": s.name, "shape": list(s.shape), "layer": s.layer,
                    "trainable": s.trainable, "offset": offset, "size": s.size,
                })
                offset += s.size
            assert offset == total

            eprec = {}
            for ep in eps:
                fname = f"{name}.{variant}.{ep}.hlo.txt"
                text = lower_entrypoint(cfg, variant, ep)
                with open(os.path.join(args.out, fname), "w") as f:
                    f.write(text)
                eprec[ep] = {"file": fname, **entry_io(cfg, variant, ep)}
            mrec["variants"][variant] = {
                "params_bin": bin_name,
                "n_params": total,
                "params": prec,
                "entrypoints": eprec,
            }
            print(f"[aot] {name}.{variant}: {total} params, "
                  f"{len(eps)} entrypoints, {time.time()-t0:.1f}s", flush=True)
        manifest["models"].append(mrec)

    manifest["fused_kernels"] = lower_fused_kernels(args.out)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    write_goldens(args.out, models)
    print(f"[aot] wrote manifest with {len(manifest['models'])} models")


def write_goldens(out_dir: str, models: list[str]) -> None:
    """Golden numerics for the Rust integration tests (tests/runtime_goldens.rs).

    For each small model/variant with a ``loss`` entrypoint: evaluate the loss
    at the shipped init params on a deterministic batch (tokens[b, s] =
    (7 b + 3 s) % vocab, labels[b] = b % 4) and record it. The Rust runtime
    must reproduce these through the compiled HLO to 1e-5.
    """
    goldens: dict = {}
    for name in models:
        if name == "lm-big":
            continue  # too slow for a unit-level golden
        cfg = M.MODEL_ZOO[name]
        b, s = cfg.batch, cfg.max_seq
        tokens = jnp.asarray(
            (7 * np.arange(b)[:, None] + 3 * np.arange(s)[None, :]) % cfg.vocab,
            jnp.int32,
        )
        labels = jnp.asarray(np.arange(b) % 4, jnp.int32)
        for variant in MATRIX[name]:
            params = M.init_params(cfg, variant, seed=0)
            pd = {sp.name: a for sp, a in zip(M.param_specs(cfg, variant), params)}
            loss = M.loss_fn(pd, tokens, labels if cfg.kind != "lm" else None,
                             cfg, variant, use_pallas=True)
            rec: dict = {"loss": float(loss)}
            if cfg.kind != "lm":
                lg = M.logits_fn(pd, tokens, cfg, variant, use_pallas=True)
                rec["logits_row0"] = [float(x) for x in lg[0]]
            goldens[f"{name}.{variant}"] = rec
    with open(os.path.join(out_dir, "goldens.json"), "w") as f:
        json.dump(goldens, f, indent=1)


if __name__ == "__main__":
    main()
