"""L1 Pallas kernel: tiled multi-head attention.

TPU-idiom tiling (see DESIGN.md §Hardware-Adaptation): the grid iterates over
(batch, head, query-block); each grid cell stages a (BQ, Dh) query tile plus
the full (Skv, Dh) key/value panels for that head into VMEM, computes a
numerically-stable softmax on the VPU, and hits the MXU twice (q·kᵀ and p·v).
This is the TPU analogue of the CUDA threadblock/shared-memory scheme the
GPU-oriented literature uses: BlockSpec expresses the HBM↔VMEM schedule that
threadblocks + __shared__ would on an A100.

``interpret=True`` is mandatory on this box — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. The kernel is still authored
with TPU block shapes so the VMEM/MXU accounting in DESIGN.md §Perf holds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, prefix_len, bq):
    """One grid cell: queries block (1, 1, BQ, Dh) vs full KV (1, 1, Skv, Dh)."""
    qi = pl.program_id(2)  # query-block index within the sequence
    q = q_ref[0, 0].astype(jnp.float32)  # (BQ, Dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (Skv, Dh)
    v = v_ref[0, 0].astype(jnp.float32)  # (Skv, Dh)

    logits = jnp.dot(q, k.T) * scale  # (BQ, Skv) — MXU
    if causal:
        skv = k.shape[0]
        row = qi * bq + jnp.arange(bq)[:, None]  # absolute query positions
        col = jnp.arange(skv)[None, :]
        mask = (col < prefix_len) | ((col - prefix_len) <= row)
        logits = jnp.where(mask, logits, -1e30)

    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.dot(p / denom, v)  # (BQ, Dh) — MXU
    o_ref[0, 0] = out.astype(o_ref.dtype)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    prefix_len: int = 0,
    block_q: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Tiled multi-head attention via Pallas.

    Shapes: q (B, H, Sq, Dh); k, v (B, H, Skv, Dh) with
    ``Skv = prefix_len + Sq`` for prefix-tuning, else ``Skv == Sq``.
    Matches :func:`kernels.ref.attention_ref` to float32 tolerance.
    """
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    if block_q is None:
        block_q = min(sq, 128)
    if sq % block_q != 0:
        raise ValueError(f"sq={sq} not divisible by block_q={block_q}")
    scale = 1.0 / (dh**0.5)

    grid = (b, h, sq // block_q)
    kernel = functools.partial(
        _attention_kernel,
        scale=scale,
        causal=causal,
        prefix_len=prefix_len,
        bq=block_q,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, skv, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, skv, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(sq: int, skv: int, dh: int, block_q: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint estimate for one grid cell (DESIGN.md §Perf input).

    q tile + k panel + v panel + logits + output, all resident at once.
    """
    q_t = block_q * dh
    kv = 2 * skv * dh
    logits = block_q * skv
    out = block_q * dh
    return dtype_bytes * (q_t + kv + logits + out)


def mxu_flops(sq: int, skv: int, dh: int) -> int:
    """MXU FLOP count per (batch, head): two matmuls."""
    return 2 * sq * skv * dh * 2
