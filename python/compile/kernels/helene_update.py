"""L1 Pallas kernels: fused HELENE optimizer update + A-GNB Hessian EMA.

The optimizer step is HELENE's second hot-spot (the first is the model
forward): at 100M parameters the unfused update is five full passes over HBM
(read theta/m/h/z, write theta/m). The fused kernel does one read + one write
per tensor, VMEM-chunked via BlockSpec — a pure VPU elementwise kernel, no MXU.

Scalars (g_scale, alpha, ...) travel as (1, 1) f32 arrays so the same lowered
HLO is reusable every step without recompilation: the Rust coordinator feeds
fresh scalar literals per step. ``interpret=True`` everywhere (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM chunk: 16k f32 = 64 KiB per operand, 6 operands ≈ 384 KiB —
# comfortably inside a TPU core's ~16 MiB VMEM with double-buffering room.
DEFAULT_BLOCK = 16384


def _update_kernel(scal_ref, theta_ref, m_ref, h_ref, z_ref, theta_out, m_out):
    g_scale = scal_ref[0, 0]
    alpha = scal_ref[0, 1]
    beta1 = scal_ref[0, 2]
    lr = scal_ref[0, 3]
    gamma = scal_ref[0, 4]
    lam = scal_ref[0, 5]
    eps = scal_ref[0, 6]
    wd = scal_ref[0, 7]

    theta = theta_ref[...]
    g = g_scale * z_ref[...]
    m_next = beta1 * m_ref[...] + alpha * g
    denom = gamma * jnp.maximum(h_ref[...], lam) + eps
    theta_out[...] = theta - lr * wd * theta - lr * m_next / denom
    m_out[...] = m_next


def helene_update(
    theta: jnp.ndarray,
    m: jnp.ndarray,
    h: jnp.ndarray,
    z: jnp.ndarray,
    scalars: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused HELENE update over a flat f32 parameter vector.

    Args:
      theta, m, h, z: (N,) f32 — parameters, momentum, Hessian diagonal,
        regenerated SPSA direction.
      scalars: (1, 8) f32 — ``[g_scale, alpha, beta1, lr, gamma, lam, eps,
        weight_decay]`` (see :func:`kernels.ref.helene_update_ref`).

    Returns ``(theta_next, m_next)``.
    """
    (n,) = theta.shape
    blk = min(block, n)
    if n % blk != 0:
        raise ValueError(f"n={n} not divisible by block={blk}")
    grid = (n // blk,)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),  # broadcast scalars
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), theta.dtype),
            jax.ShapeDtypeStruct((n,), m.dtype),
        ],
        interpret=interpret,
    )(scalars, theta, m, h, z)


def _agnb_kernel(scal_ref, h_ref, z_ref, h_out):
    g_scale = scal_ref[0, 0]
    batch = scal_ref[0, 1]
    beta2 = scal_ref[0, 2]
    g = g_scale * z_ref[...]
    h_hat = batch * g * g
    h_out[...] = beta2 * h_ref[...] + (1.0 - beta2) * h_hat


def agnb_ema(
    h: jnp.ndarray,
    z: jnp.ndarray,
    scalars: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """A-GNB Hessian-diagonal EMA over a flat f32 vector.

    ``scalars``: (1, 3) f32 — ``[g_scale, batch_size, beta2]``.
    Matches :func:`kernels.ref.agnb_ema_ref`.
    """
    (n,) = h.shape
    blk = min(block, n)
    if n % blk != 0:
        raise ValueError(f"n={n} not divisible by block={blk}")
    grid = (n // blk,)
    return pl.pallas_call(
        _agnb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), h.dtype),
        interpret=interpret,
    )(scalars, h, z)


def hbm_traffic_bytes(n: int, fused: bool) -> int:
    """HBM bytes moved by one update step (DESIGN.md §Perf input)."""
    if fused:
        return 4 * n * (4 + 2)  # read theta/m/h/z, write theta/m
    # unfused: g=g_s*z (r z, w g); m=b m+a g (r m,g, w m); denom (r h, w d);
    # theta (r theta,m,d, w theta)
    return 4 * n * (1 + 1 + 2 + 1 + 1 + 1 + 3 + 1)
