"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from compile.kernels.attention import attention, mxu_flops, vmem_bytes
from compile.kernels.helene_update import agnb_ema, hbm_traffic_bytes, helene_update
from compile.kernels.ref import agnb_ema_ref, attention_ref, helene_update_ref

__all__ = [
    "attention",
    "attention_ref",
    "helene_update",
    "helene_update_ref",
    "agnb_ema",
    "agnb_ema_ref",
    "vmem_bytes",
    "mxu_flops",
    "hbm_traffic_bytes",
]
