"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle here to float tolerance (see python/tests/). They are
also used directly by model.py when ``use_pallas=False`` so that the model
itself can be differentially tested against its kernelised form.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    prefix_len: int = 0,
) -> jnp.ndarray:
    """Multi-head scaled dot-product attention oracle.

    Args:
      q: (B, H, Sq, Dh) queries.
      k: (B, H, Skv, Dh) keys. ``Skv = prefix_len + Sq`` when a learnable
         prefix is prepended (prefix-tuning); otherwise ``Skv == Sq``.
      v: (B, H, Skv, Dh) values.
      causal: apply a causal mask. Query i may attend to every prefix
        position plus key positions ``j - prefix_len <= i``.
      prefix_len: number of leading key/value positions that are a
        learnable prefix (always attendable).

    Returns:
      (B, H, Sq, Dh) attention output.
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        sq = q.shape[2]
        skv = k.shape[2]
        qi = jnp.arange(sq)[:, None]
        kj = jnp.arange(skv)[None, :]
        mask = (kj < prefix_len) | ((kj - prefix_len) <= qi)
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def helene_update_ref(
    theta: jnp.ndarray,
    m: jnp.ndarray,
    h: jnp.ndarray,
    z: jnp.ndarray,
    *,
    g_scale,
    alpha,
    beta1,
    lr,
    gamma,
    lam,
    eps,
    weight_decay,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the fused HELENE parameter update (Algorithm 1, lines 7 + 13-14).

    The SPSA gradient for this layer is ``g = g_scale * z`` (MeZO's seeded
    regeneration: ``z`` is the layer's slice of the perturbation direction and
    ``g_scale = (L+ - L-) / (2 eps_spsa)``).

    Returns ``(theta_next, m_next)``::

      m_next     = beta1 * m + alpha * g
      denom      = gamma * max(h, lam) + eps
      theta_next = theta - lr * weight_decay * theta - lr * m_next / denom
    """
    g = g_scale * z
    m_next = beta1 * m + alpha * g
    denom = gamma * jnp.maximum(h, lam) + eps
    theta_next = theta - lr * weight_decay * theta - lr * m_next / denom
    return theta_next, m_next


def agnb_ema_ref(
    h: jnp.ndarray,
    z: jnp.ndarray,
    *,
    g_scale,
    batch,
    beta2,
) -> jnp.ndarray:
    """Oracle for the A-GNB diagonal-Hessian EMA step (Alg. 1 line 10; Alg. 2).

    The zeroth-order A-GNB estimate of the Hessian diagonal is
    ``h_hat = B * g ⊙ g`` with ``g = g_scale * z`` (Algorithm 2 returns
    ``B · ĝ ⊙ ĝ``). The EMA is ``h' = beta2 * h + (1 - beta2) * h_hat``.
    """
    g = g_scale * z
    h_hat = batch * g * g
    return beta2 * h + (1.0 - beta2) * h_hat
