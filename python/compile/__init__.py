"""Build-time compile package: L2 JAX models + L1 Pallas kernels + AOT lowering.

Nothing in this package is imported at runtime; `make artifacts` runs
``python -m compile.aot`` once and the Rust coordinator consumes the
resulting HLO text + manifest.
"""
