"""L2: JAX transformer models (build-time only) — forward, loss, grad, jvp.

Three architectures back the paper's experiment matrix (DESIGN.md §3):

* ``cls``   — encoder classifier ("roberta-lite" stand-in for RoBERTa-large):
              bidirectional attention, mean-pool, linear head.
* ``dec``   — decoder classifier ("opt-lite" stand-in for OPT-1.3B used as a
              classifier): causal attention, last-position pool, linear head.
* ``lm``    — causal language model (next-token CE) for the end-to-end
              100M-parameter training example.

Each architecture is compiled per tuning *variant* — ``ft`` (all parameters
trainable), ``lora`` (LoRA adapters on W_q/W_v; base frozen), ``prefix``
(learnable per-layer prefix KV; base frozen) — and per *entrypoint*:

* ``loss``      : (params…, tokens[, labels]) → (loss,)            [ZO path]
* ``logits``    : (params…, tokens)           → (logits,)           [eval]
* ``loss_grad`` : (params…, tokens[, labels]) → (loss, grads…)      [FO path]
* ``loss_jvp``  : (params…, tangents…, tokens[, labels]) → (loss, jvp)
                                                            [Forward-Grad]

The ZO entrypoints run the L1 Pallas attention kernel (interpret-lowered so
it executes on CPU PJRT). The differentiated entrypoints use the pure-jnp
oracle ``attention_ref`` — interpret-mode ``pallas_call`` has no JVP rule —
which python/tests/ verifies is numerically identical to the kernel, so both
paths compute the same function.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref

LN_EPS = 1e-5


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration of one compiled model family."""

    name: str
    kind: str  # "cls" | "dec" | "lm"
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    max_seq: int
    n_classes: int  # classifier head width (ignored for kind == "lm")
    batch: int
    lora_rank: int = 4
    lora_alpha: float = 8.0
    prefix_len: int = 4

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def causal(self) -> bool:
        return self.kind in ("dec", "lm")


# The model zoo compiled by aot.py. Sizes are chosen for a 1-core CPU box;
# `lm-big` is the ~100M-parameter end-to-end configuration (DESIGN.md §3).
MODEL_ZOO: dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in [
        ModelConfig("cls-tiny", "cls", vocab=64, d_model=32, n_heads=2, n_layers=2,
                    d_ff=64, max_seq=16, n_classes=8, batch=4, lora_rank=2,
                    prefix_len=2),
        ModelConfig("cls-small", "cls", vocab=512, d_model=128, n_heads=4,
                    n_layers=4, d_ff=512, max_seq=32, n_classes=8, batch=8),
        ModelConfig("dec-small", "dec", vocab=512, d_model=128, n_heads=4,
                    n_layers=4, d_ff=512, max_seq=32, n_classes=8, batch=8),
        ModelConfig("lm-small", "lm", vocab=512, d_model=128, n_heads=4,
                    n_layers=4, d_ff=512, max_seq=32, n_classes=0, batch=8),
        ModelConfig("lm-big", "lm", vocab=8192, d_model=768, n_heads=12,
                    n_layers=12, d_ff=3072, max_seq=64, n_classes=0, batch=2),
    ]
}

VARIANTS = ("ft", "lora", "prefix")


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter array in manifest order."""

    name: str
    shape: tuple[int, ...]
    layer: str  # layer group for layer-wise clipping (e.g. "block2.attn")
    trainable: bool

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def param_specs(cfg: ModelConfig, variant: str) -> list[ParamSpec]:
    """The ordered parameter layout for (model, variant).

    Order is authoring order and is the manifest contract with the Rust
    coordinator: params.bin, loss_grad outputs, and jvp tangents all follow
    this exact ordering.
    """
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    d, f, s, v = cfg.d_model, cfg.d_ff, cfg.max_seq, cfg.vocab
    base_trainable = variant == "ft"
    specs: list[ParamSpec] = [
        ParamSpec("embed.tok", (v, d), "embed", base_trainable),
        ParamSpec("embed.pos", (s, d), "embed", base_trainable),
    ]
    for i in range(cfg.n_layers):
        blk = f"block{i}"
        specs += [
            ParamSpec(f"{blk}.ln1.scale", (d,), f"{blk}.attn", base_trainable),
            ParamSpec(f"{blk}.ln1.bias", (d,), f"{blk}.attn", base_trainable),
            ParamSpec(f"{blk}.attn.wq", (d, d), f"{blk}.attn", base_trainable),
            ParamSpec(f"{blk}.attn.wk", (d, d), f"{blk}.attn", base_trainable),
            ParamSpec(f"{blk}.attn.wv", (d, d), f"{blk}.attn", base_trainable),
            ParamSpec(f"{blk}.attn.wo", (d, d), f"{blk}.attn", base_trainable),
            ParamSpec(f"{blk}.ln2.scale", (d,), f"{blk}.mlp", base_trainable),
            ParamSpec(f"{blk}.ln2.bias", (d,), f"{blk}.mlp", base_trainable),
            ParamSpec(f"{blk}.mlp.w1", (d, f), f"{blk}.mlp", base_trainable),
            ParamSpec(f"{blk}.mlp.b1", (f,), f"{blk}.mlp", base_trainable),
            ParamSpec(f"{blk}.mlp.w2", (f, d), f"{blk}.mlp", base_trainable),
            ParamSpec(f"{blk}.mlp.b2", (d,), f"{blk}.mlp", base_trainable),
        ]
        if variant == "lora":
            r = cfg.lora_rank
            specs += [
                ParamSpec(f"{blk}.lora.q.a", (d, r), f"{blk}.lora", True),
                ParamSpec(f"{blk}.lora.q.b", (r, d), f"{blk}.lora", True),
                ParamSpec(f"{blk}.lora.v.a", (d, r), f"{blk}.lora", True),
                ParamSpec(f"{blk}.lora.v.b", (r, d), f"{blk}.lora", True),
            ]
        if variant == "prefix":
            p = cfg.prefix_len
            specs += [
                ParamSpec(f"{blk}.prefix.k", (p, d), f"{blk}.prefix", True),
                ParamSpec(f"{blk}.prefix.v", (p, d), f"{blk}.prefix", True),
            ]
    specs += [
        ParamSpec("final_ln.scale", (d,), "head", base_trainable),
        ParamSpec("final_ln.bias", (d,), "head", base_trainable),
    ]
    if cfg.kind == "lm":
        specs.append(ParamSpec("head.w", (d, v), "head", base_trainable))
    else:
        # The classifier head is always trainable: PEFT fine-tuning keeps a
        # task head, matching the MeZO/HELENE experimental protocol.
        specs.append(ParamSpec("head.w", (d, cfg.n_classes), "head", True))
        specs.append(ParamSpec("head.b", (cfg.n_classes,), "head", True))
    return specs


def init_params(cfg: ModelConfig, variant: str, seed: int = 0) -> list[jnp.ndarray]:
    """Deterministic initialisation following the specs order.

    GPT-2-style: normal(0.02) embeddings and projections with 1/sqrt(2L)
    scaling on residual-writing matrices; LayerNorm at identity; LoRA B and
    prefix start at ~zero so the PEFT variants begin exactly at the base
    model's function (verified in tests).
    """
    specs = param_specs(cfg, variant)
    key = jax.random.PRNGKey(seed)
    out: list[jnp.ndarray] = []
    resid_scale = 1.0 / math.sqrt(2.0 * cfg.n_layers)
    for spec in specs:
        key, sub = jax.random.split(key)
        leaf = spec.name.split(".")[-1]
        if "ln" in spec.name and leaf == "scale":
            arr = jnp.ones(spec.shape, jnp.float32)
        elif leaf in ("bias", "b1", "b2", "b") and "lora" not in spec.name:
            arr = jnp.zeros(spec.shape, jnp.float32)
        elif ".lora." in spec.name and leaf == "b":
            arr = jnp.zeros(spec.shape, jnp.float32)
        elif ".prefix." in spec.name:
            arr = 0.01 * jax.random.normal(sub, spec.shape, jnp.float32)
        else:
            std = 0.02
            if leaf in ("wo", "w2"):
                std *= resid_scale
            arr = std * jax.random.normal(sub, spec.shape, jnp.float32)
        out.append(arr)
    return out


def _layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * scale + bias


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def forward(
    params: dict[str, jnp.ndarray],
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    variant: str,
    *,
    use_pallas: bool,
) -> jnp.ndarray:
    """Transformer trunk → (B, S, D) final-LN hidden states."""
    b, s = tokens.shape
    x = params["embed.tok"][tokens] + params["embed.pos"][None, :s]
    attn_fn = attention if use_pallas else attention_ref
    prefix_len = cfg.prefix_len if variant == "prefix" else 0

    for i in range(cfg.n_layers):
        blk = f"block{i}"
        xn = _layernorm(x, params[f"{blk}.ln1.scale"], params[f"{blk}.ln1.bias"])
        q = xn @ params[f"{blk}.attn.wq"]
        k = xn @ params[f"{blk}.attn.wk"]
        v = xn @ params[f"{blk}.attn.wv"]
        if variant == "lora":
            lscale = cfg.lora_alpha / cfg.lora_rank
            q = q + lscale * (xn @ params[f"{blk}.lora.q.a"]) @ params[f"{blk}.lora.q.b"]
            v = v + lscale * (xn @ params[f"{blk}.lora.v.a"]) @ params[f"{blk}.lora.v.b"]
        qh, kh, vh = (_split_heads(t, cfg.n_heads) for t in (q, k, v))
        if variant == "prefix":
            pk = _split_heads(
                jnp.broadcast_to(params[f"{blk}.prefix.k"][None], (b, prefix_len, cfg.d_model)),
                cfg.n_heads,
            )
            pv = _split_heads(
                jnp.broadcast_to(params[f"{blk}.prefix.v"][None], (b, prefix_len, cfg.d_model)),
                cfg.n_heads,
            )
            kh = jnp.concatenate([pk, kh], axis=2)
            vh = jnp.concatenate([pv, vh], axis=2)
        att = attn_fn(qh, kh, vh, causal=cfg.causal, prefix_len=prefix_len)
        x = x + _merge_heads(att) @ params[f"{blk}.attn.wo"]

        xn = _layernorm(x, params[f"{blk}.ln2.scale"], params[f"{blk}.ln2.bias"])
        hmid = jax.nn.gelu(xn @ params[f"{blk}.mlp.w1"] + params[f"{blk}.mlp.b1"])
        x = x + hmid @ params[f"{blk}.mlp.w2"] + params[f"{blk}.mlp.b2"]

    return _layernorm(x, params["final_ln.scale"], params["final_ln.bias"])


def logits_fn(
    params: dict[str, jnp.ndarray],
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    variant: str,
    *,
    use_pallas: bool,
) -> jnp.ndarray:
    """Classifier logits (B, C) for cls/dec kinds; LM logits (B, S, V) for lm."""
    hidden = forward(params, tokens, cfg, variant, use_pallas=use_pallas)
    if cfg.kind == "lm":
        return hidden @ params["head.w"]
    if cfg.kind == "cls":
        pooled = jnp.mean(hidden, axis=1)
    else:  # dec: causal model — only the last position sees the whole input
        pooled = hidden[:, -1]
    return pooled @ params["head.w"] + params["head.b"]


def _softmax_ce(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - picked)


def loss_fn(
    params: dict[str, jnp.ndarray],
    tokens: jnp.ndarray,
    labels: jnp.ndarray | None,
    cfg: ModelConfig,
    variant: str,
    *,
    use_pallas: bool,
) -> jnp.ndarray:
    """Mean cross-entropy. For ``lm`` kind labels are the shifted tokens."""
    lg = logits_fn(params, tokens, cfg, variant, use_pallas=use_pallas)
    if cfg.kind == "lm":
        return _softmax_ce(lg[:, :-1], tokens[:, 1:])
    assert labels is not None
    return _softmax_ce(lg, labels)


# --------------------------------------------------------------------------
# Entrypoint builders: positional flat-param functions ready for jax.jit.
# --------------------------------------------------------------------------


def _to_dict(specs: list[ParamSpec], flat: tuple[jnp.ndarray, ...]) -> dict[str, jnp.ndarray]:
    return {s.name: a for s, a in zip(specs, flat)}


def build_entrypoints(
    cfg: ModelConfig, variant: str
) -> dict[str, tuple[Callable, list[jax.ShapeDtypeStruct]]]:
    """Return {entrypoint: (fn, example_arg_specs)} for AOT lowering.

    Every fn returns a tuple (lowered with return_tuple=True; the Rust side
    unwraps). Data arguments come after params (and after tangents for jvp).
    """
    specs = param_specs(cfg, variant)
    n = len(specs)
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.max_seq), jnp.int32)
    lbl_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    p_specs = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in specs]
    has_labels = cfg.kind != "lm"

    def loss_ep(*args):
        params = _to_dict(specs, args[:n])
        tokens = args[n]
        labels = args[n + 1] if has_labels else None
        return (loss_fn(params, tokens, labels, cfg, variant, use_pallas=True),)

    def logits_ep(*args):
        params = _to_dict(specs, args[:n])
        return (logits_fn(params, args[n], cfg, variant, use_pallas=True),)

    # Oracle-attention twins of loss/logits. Numerically identical to the
    # Pallas graphs (pytest-verified); compiled so the CPU-bound experiment
    # sweeps can opt out of interpret-mode Pallas overhead (HELENE_REF_ATTN).
    # On a real TPU the Pallas graph is the fast one — see DESIGN.md §Perf.
    def loss_ref_ep(*args):
        params = _to_dict(specs, args[:n])
        tokens = args[n]
        labels = args[n + 1] if has_labels else None
        return (loss_fn(params, tokens, labels, cfg, variant, use_pallas=False),)

    def logits_ref_ep(*args):
        params = _to_dict(specs, args[:n])
        return (logits_fn(params, args[n], cfg, variant, use_pallas=False),)

    def loss_grad_ep(*args):
        tokens = args[n]
        labels = args[n + 1] if has_labels else None

        def scalar_loss(flat):
            return loss_fn(_to_dict(specs, flat), tokens, labels, cfg, variant,
                           use_pallas=False)

        val, grads = jax.value_and_grad(scalar_loss)(tuple(args[:n]))
        return (val, *grads)

    def loss_jvp_ep(*args):
        primals = tuple(args[:n])
        tangents = tuple(args[n : 2 * n])
        tokens = args[2 * n]
        labels = args[2 * n + 1] if has_labels else None

        def scalar_loss(flat):
            return loss_fn(_to_dict(specs, flat), tokens, labels, cfg, variant,
                           use_pallas=False)

        val, jvp = jax.jvp(scalar_loss, (primals,), (tangents,))
        return (val, jvp)

    data = [tok_spec] + ([lbl_spec] if has_labels else [])
    eps = {
        "loss": (loss_ep, p_specs + data),
        "logits": (logits_ep, p_specs + [tok_spec]),
        "loss_ref": (loss_ref_ep, p_specs + data),
        "logits_ref": (logits_ref_ep, p_specs + [tok_spec]),
        "loss_grad": (loss_grad_ep, p_specs + data),
        "loss_jvp": (loss_jvp_ep, p_specs + p_specs + data),
    }
    return eps


def n_params(cfg: ModelConfig, variant: str = "ft") -> int:
    return sum(s.size for s in param_specs(cfg, variant))
