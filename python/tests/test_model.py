"""L2 model correctness: losses, grads, variants, parameter layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.MODEL_ZOO["cls-tiny"]


def _params_dict(cfg, variant, seed=0):
    specs = M.param_specs(cfg, variant)
    params = M.init_params(cfg, variant, seed=seed)
    return specs, params, {s.name: a for s, a in zip(specs, params)}


def _batch(cfg, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    tokens = jax.random.randint(k1, (cfg.batch, cfg.max_seq), 0, cfg.vocab)
    labels = jax.random.randint(k2, (cfg.batch,), 0, 4)
    return tokens, labels


@pytest.mark.parametrize("variant", M.VARIANTS)
def test_pallas_and_ref_paths_agree(variant):
    _, _, pd = _params_dict(CFG, variant)
    tokens, labels = _batch(CFG)
    l1 = M.loss_fn(pd, tokens, labels, CFG, variant, use_pallas=True)
    l2 = M.loss_fn(pd, tokens, labels, CFG, variant, use_pallas=False)
    np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", ["cls-tiny", "cls-small", "dec-small", "lm-small"])
def test_loss_is_finite_and_near_uniform_at_init(name):
    cfg = M.MODEL_ZOO[name]
    _, _, pd = _params_dict(cfg, "ft")
    tokens, labels = _batch(cfg)
    loss = M.loss_fn(pd, tokens, labels if cfg.kind != "lm" else None, cfg, "ft",
                     use_pallas=False)
    assert np.isfinite(loss)
    n_out = cfg.vocab if cfg.kind == "lm" else cfg.n_classes
    # near-uniform prediction at init: CE ≈ ln(n_out) within 30%
    assert abs(float(loss) - np.log(n_out)) < 0.3 * np.log(n_out)


def test_lora_init_matches_base_function():
    """LoRA B = 0 at init → lora forward == ft forward with shared base."""
    specs_ft, params_ft, pd_ft = _params_dict(CFG, "ft")
    specs_lo, params_lo, pd_lo = _params_dict(CFG, "lora")
    # overwrite lora base params with the ft ones (same names)
    for s in specs_lo:
        if s.name in pd_ft:
            pd_lo[s.name] = pd_ft[s.name]
    tokens, labels = _batch(CFG)
    l_ft = M.loss_fn(pd_ft, tokens, labels, CFG, "ft", use_pallas=False)
    l_lo = M.loss_fn(pd_lo, tokens, labels, CFG, "lora", use_pallas=False)
    np.testing.assert_allclose(l_ft, l_lo, rtol=1e-6)


def test_grad_matches_finite_difference():
    cfg = CFG
    specs, params, _ = _params_dict(cfg, "ft")
    tokens, labels = _batch(cfg)
    eps = M.build_entrypoints(cfg, "ft")
    out = eps["loss_grad"][0](*params, tokens, labels)
    loss0, grads = out[0], out[1:]
    assert len(grads) == len(params)

    # central finite difference on a few random coordinates of head.w
    idx = [s.name for s in specs].index("head.w")
    g = np.asarray(grads[idx])
    rng = np.random.default_rng(0)
    loss_f = eps["loss"][0]
    for _ in range(3):
        i = rng.integers(0, params[idx].shape[0])
        j = rng.integers(0, params[idx].shape[1])
        h = 1e-3
        pp = [p for p in params]
        pp[idx] = params[idx].at[i, j].add(h)
        lp = loss_f(*pp, tokens, labels)[0]
        pp[idx] = params[idx].at[i, j].add(-h)
        lm = loss_f(*pp, tokens, labels)[0]
        fd = (float(lp) - float(lm)) / (2 * h)
        np.testing.assert_allclose(g[i, j], fd, rtol=5e-2, atol=1e-4)


def test_jvp_matches_grad_dot_tangent():
    cfg = CFG
    _, params, _ = _params_dict(cfg, "ft")
    tokens, labels = _batch(cfg)
    eps = M.build_entrypoints(cfg, "ft")
    key = jax.random.PRNGKey(3)
    tangents = []
    for p in params:
        key, sub = jax.random.split(key)
        tangents.append(jax.random.normal(sub, p.shape, jnp.float32))
    loss1, jvp = eps["loss_jvp"][0](*params, *tangents, tokens, labels)
    out = eps["loss_grad"][0](*params, tokens, labels)
    dot = sum(jnp.vdot(g, t) for g, t in zip(out[1:], tangents))
    np.testing.assert_allclose(jvp, dot, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(loss1, out[0], rtol=1e-6)


@pytest.mark.parametrize("variant", M.VARIANTS)
def test_param_spec_layout_consistency(variant):
    """Manifest contract: specs are unique, ordered, sizes match init arrays."""
    specs = M.param_specs(CFG, variant)
    params = M.init_params(CFG, variant)
    assert len(specs) == len(params)
    names = [s.name for s in specs]
    assert len(set(names)) == len(names)
    for s, p in zip(specs, params):
        assert tuple(p.shape) == s.shape
        assert s.size == int(np.prod(s.shape))
    if variant == "ft":
        assert all(s.trainable for s in specs)
    else:
        marker = ".lora." if variant == "lora" else ".prefix."
        for s in specs:
            if marker in s.name or s.name.startswith("head."):
                assert s.trainable, s.name
            else:
                assert not s.trainable, s.name


def test_layer_groups_cover_all_blocks():
    specs = M.param_specs(CFG, "ft")
    groups = {s.layer for s in specs}
    assert "embed" in groups and "head" in groups
    for i in range(CFG.n_layers):
        assert f"block{i}.attn" in groups
        assert f"block{i}.mlp" in groups


def test_causal_dec_ignores_future_tokens():
    """dec pooling reads the last position; perturbing token t<S-1 changes it,
    but a cls-kind mean-pool on causal=False sees everything — sanity check
    that the dec model is actually causal: logits at position 0 of the LM
    must not depend on later tokens."""
    cfg = M.MODEL_ZOO["lm-small"]
    _, params, pd = _params_dict(cfg, "ft")
    tokens, _ = _batch(cfg)
    lg = M.logits_fn(pd, tokens, cfg, "ft", use_pallas=False)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab)
    lg2 = M.logits_fn(pd, tokens2, cfg, "ft", use_pallas=False)
    np.testing.assert_allclose(lg[:, :-1], lg2[:, :-1], rtol=1e-5, atol=1e-5)


def test_entrypoint_arity():
    for variant in M.VARIANTS:
        n = len(M.param_specs(CFG, variant))
        eps = M.build_entrypoints(CFG, variant)
        assert len(eps["loss"][1]) == n + 2
        assert len(eps["logits"][1]) == n + 1
        assert len(eps["loss_jvp"][1]) == 2 * n + 2


def test_n_params_scales():
    assert M.n_params(M.MODEL_ZOO["lm-big"]) > 90_000_000
    assert M.n_params(M.MODEL_ZOO["cls-tiny"]) < 50_000
