"""AOT pipeline: HLO emission, manifest layout, params.bin round-trip."""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_smoke(tmp_path):
    text = aot.lower_entrypoint(M.MODEL_ZOO["cls-tiny"], "ft", "loss")
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple return contract for the rust unwrapper
    assert "tuple" in text.lower()


@pytest.mark.parametrize("ep", ["loss", "logits", "loss_grad", "loss_jvp"])
def test_entry_io_shapes(ep):
    cfg = M.MODEL_ZOO["cls-tiny"]
    io = aot.entry_io(cfg, "ft", ep)
    assert "inputs" in io and "outputs" in io
    if ep == "loss_grad":
        n = len(M.param_specs(cfg, "ft"))
        assert len(io["outputs"]) == 1 + n


def test_params_bin_round_trip(tmp_path):
    cfg = M.MODEL_ZOO["cls-tiny"]
    params = M.init_params(cfg, "ft", seed=0)
    path = str(tmp_path / "p.bin")
    total = aot.write_params_bin(path, params)
    assert total == M.n_params(cfg)
    raw = np.fromfile(path, dtype="<f4")
    assert raw.size == total
    offset = 0
    for p in params:
        n = p.size
        np.testing.assert_array_equal(raw[offset : offset + n], np.asarray(p).ravel())
        offset += n


def test_manifest_offsets_contiguous(tmp_path):
    """Emit a tiny manifest end-to-end and validate the offset invariants."""
    out = str(tmp_path)
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", out, "--models", "cls-tiny"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(os.path.join(out, "manifest.json")) as f:
        man = json.load(f)
    assert man["format"] == 1
    (model,) = man["models"]
    assert model["name"] == "cls-tiny"
    for variant, vrec in model["variants"].items():
        offset = 0
        for prec in vrec["params"]:
            assert prec["offset"] == offset
            assert prec["size"] == int(np.prod(prec["shape"]))
            offset += prec["size"]
        assert offset == vrec["n_params"]
        bin_path = os.path.join(out, vrec["params_bin"])
        assert os.path.getsize(bin_path) == 4 * vrec["n_params"]
        for ep, erec in vrec["entrypoints"].items():
            assert os.path.exists(os.path.join(out, erec["file"]))
    # goldens were produced alongside
    with open(os.path.join(out, "goldens.json")) as f:
        goldens = json.load(f)
    assert "cls-tiny.ft" in goldens
    assert np.isfinite(goldens["cls-tiny.ft"]["loss"])


def test_fused_kernel_artifacts(tmp_path):
    entries = aot.lower_fused_kernels(str(tmp_path))
    assert [e["n"] for e in entries] == aot.FUSED_SIZES
    for e in entries:
        for key in ("update_file", "ema_file"):
            with open(os.path.join(str(tmp_path), e[key])) as f:
                assert "HloModule" in f.read(200)


def test_matrix_covers_design_doc():
    """Every experiment in DESIGN.md §5 has its artifacts compiled."""
    assert set(aot.MATRIX) == set(M.MODEL_ZOO)
    # tables 1-3 need all three tuning variants on the small models
    for name in ("cls-small", "dec-small"):
        assert set(aot.MATRIX[name]) == {"ft", "lora", "prefix"}
        for variant in aot.MATRIX[name]:
            assert "loss" in aot.MATRIX[name][variant]      # ZO path
            assert "loss_grad" in aot.MATRIX[name][variant]  # FO baselines
    # end-to-end example needs the big LM training path
    assert "loss_grad" in aot.MATRIX["lm-big"]["ft"]
