"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes/dtypes/values; every property asserts allclose
against ref.py. These tests gate `make artifacts` quality: if they fail, the
HLO the Rust coordinator executes is wrong.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, mxu_flops, vmem_bytes
from compile.kernels.helene_update import agnb_ema, helene_update
from compile.kernels.ref import agnb_ema_ref, attention_ref, helene_update_ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    log_s=st.integers(2, 5),
    dh=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(b, h, log_s, dh, causal, seed):
    s = 2**log_s
    q = _rand(seed, (b, h, s, dh), jnp.float32)
    k = _rand(seed + 1, (b, h, s, dh), jnp.float32)
    v = _rand(seed + 2, (b, h, s, dh), jnp.float32)
    got = attention(q, k, v, causal=causal, block_q=min(s, 8))
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    p=st.integers(1, 6),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_prefix_matches_ref(p, causal, seed):
    b, h, s, dh = 2, 2, 8, 8
    q = _rand(seed, (b, h, s, dh), jnp.float32)
    k = _rand(seed + 1, (b, h, s + p, dh), jnp.float32)
    v = _rand(seed + 2, (b, h, s + p, dh), jnp.float32)
    got = attention(q, k, v, causal=causal, prefix_len=p, block_q=4)
    want = attention_ref(q, k, v, causal=causal, prefix_len=p)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_dtypes(dtype):
    b, h, s, dh = 2, 2, 16, 8
    q, k, v = (_rand(i, (b, h, s, dh), dtype) for i in range(3))
    got = attention(q, k, v, block_q=8)
    want = attention_ref(q, k, v)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **_tol(dtype)
    )


def test_attention_block_q_invariance():
    """Tiling must not change the result: all block sizes agree."""
    b, h, s, dh = 1, 2, 32, 8
    q, k, v = (_rand(i + 10, (b, h, s, dh), jnp.float32) for i in range(3))
    outs = [attention(q, k, v, causal=True, block_q=bq) for bq in (4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_attention_causal_masks_future():
    """Perturbing a future token must not change earlier outputs."""
    b, h, s, dh = 1, 1, 8, 4
    q, k, v = (_rand(i + 20, (b, h, s, dh), jnp.float32) for i in range(3))
    base = attention(q, k, v, causal=True, block_q=4)
    k2 = k.at[:, :, -1].add(7.0)
    v2 = v.at[:, :, -1].add(-3.0)
    pert = attention(q, k2, v2, causal=True, block_q=4)
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1], rtol=1e-6, atol=1e-6)


def test_attention_rejects_bad_block():
    q = jnp.zeros((1, 1, 6, 4))
    with pytest.raises(ValueError):
        attention(q, q, q, block_q=4)


def test_accounting_helpers_positive():
    assert vmem_bytes(32, 32, 16, 16) > 0
    assert mxu_flops(32, 32, 16) == 2 * 32 * 32 * 16 * 2


# ------------------------------------------------------------ fused update


@settings(**SETTINGS)
@given(
    log_n=st.integers(4, 10),
    g_scale=st.floats(-3, 3),
    alpha=st.floats(0.0, 1.0),
    beta1=st.floats(0.0, 0.999),
    lam=st.floats(1e-3, 3.0),
    wd=st.floats(0.0, 0.1),
    seed=st.integers(0, 2**16),
)
def test_helene_update_matches_ref(log_n, g_scale, alpha, beta1, lam, wd, seed):
    n = 2**log_n
    theta, m, z = (_rand(seed + i, (n,), jnp.float32) for i in range(3))
    h = jnp.abs(_rand(seed + 3, (n,), jnp.float32))
    lr, gamma, eps = 1e-3, 1.0, 1e-8
    sc = jnp.array([[g_scale, alpha, beta1, lr, gamma, lam, eps, wd]], jnp.float32)
    t1, m1 = helene_update(theta, m, h, z, sc, block=min(n, 64))
    t2, m2 = helene_update_ref(
        theta, m, h, z, g_scale=g_scale, alpha=alpha, beta1=beta1, lr=lr,
        gamma=gamma, lam=lam, eps=eps, weight_decay=wd,
    )
    np.testing.assert_allclose(t1, t2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    log_n=st.integers(4, 10),
    g_scale=st.floats(-3, 3),
    batch=st.sampled_from([1.0, 4.0, 16.0]),
    beta2=st.floats(0.0, 0.9999),
    seed=st.integers(0, 2**16),
)
def test_agnb_ema_matches_ref(log_n, g_scale, batch, beta2, seed):
    n = 2**log_n
    h = jnp.abs(_rand(seed, (n,), jnp.float32))
    z = _rand(seed + 1, (n,), jnp.float32)
    sc = jnp.array([[g_scale, batch, beta2]], jnp.float32)
    got = agnb_ema(h, z, sc, block=min(n, 64))
    want = agnb_ema_ref(h, z, g_scale=g_scale, batch=batch, beta2=beta2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_helene_update_clip_floor_semantics():
    """Where h < lam the denominator uses lam: update magnitude is bounded."""
    n = 64
    theta = jnp.zeros((n,))
    m = jnp.zeros((n,))
    h = jnp.zeros((n,))  # pathological flat curvature
    z = jnp.ones((n,))
    lam, lr, gamma, eps = 1.0, 0.1, 1.0, 0.0
    sc = jnp.array([[1.0, 1.0, 0.0, lr, gamma, lam, eps, 0.0]], jnp.float32)
    t1, m1 = helene_update(theta, m, h, z, sc, block=n)
    # m = z, denom = lam => step = lr * 1 / 1
    np.testing.assert_allclose(t1, -lr * jnp.ones((n,)), rtol=1e-6)


def test_helene_update_block_invariance():
    n = 256
    theta, m, z = (_rand(i + 40, (n,), jnp.float32) for i in range(3))
    h = jnp.abs(_rand(44, (n,), jnp.float32))
    sc = jnp.array([[0.5, 0.9, 0.9, 1e-2, 1.0, 0.1, 1e-8, 0.0]], jnp.float32)
    ref_t, ref_m = helene_update(theta, m, h, z, sc, block=n)
    for blk in (16, 32, 64, 128):
        t, mm = helene_update(theta, m, h, z, sc, block=blk)
        np.testing.assert_allclose(t, ref_t, rtol=1e-6)
        np.testing.assert_allclose(mm, ref_m, rtol=1e-6)
